//! Typed DTO layer of the `/v1` REST API (paper §3.4/§4.1).
//!
//! Every payload that crosses the wire has a typed shape here with an
//! explicit `to_json` / `from_json` codec, validated **at the edge**:
//! unknown fields, missing required fields, and wrong scalar types are
//! `400 invalid` — never silently defaulted.  The same types back the
//! [`crate::sdk::AcaiApi`] trait, so the in-process client and the
//! remote wire client speak identical structures (round-tripping them
//! through these codecs is what the conformance suite proves).

use crate::autoprovision::{Decision, Objective};
use crate::cluster::{
    ClusterCounters, NodeSnapshot, NodeSpec, PoolConfig, PoolSnapshot, ResourceConfig,
};
use crate::datalake::metadata::ArtifactKind;
use crate::datalake::{Branch, ChangedEntry, Commit, CommitDiff, DiffEntry};
use crate::datalake::gc::GcReport;
use crate::datalake::timetravel::RollbackReport;
use crate::docstore::{Clause, IndexKey};
use crate::engine::{
    ExperimentSpec, ExperimentStatus, JobRecord, Priority, ProjectShare,
    SchedulerCounters, SweepStrategy, TrialStatus,
};
use crate::error::{AcaiError, Result};
use crate::ids::{ExperimentId, JobId, Version};
use crate::json::{Json, JsonObject};
use crate::sdk::JobRequest;

pub use crate::engine::MetricMode;

use super::router::Query;

// ---------------------------------------------------------------------
// strict object readers
// ---------------------------------------------------------------------

/// The body must be a JSON object.
pub fn as_object(v: &Json) -> Result<&JsonObject> {
    v.as_object()
        .ok_or_else(|| AcaiError::invalid("request body must be a JSON object"))
}

/// Reject unknown fields — the edge never guesses what a typo meant.
pub fn check_fields(obj: &JsonObject, allowed: &[&str]) -> Result<()> {
    for key in obj.keys() {
        if !allowed.contains(&key) {
            return Err(AcaiError::invalid(format!("unknown field {key:?}")));
        }
    }
    Ok(())
}

/// Required string field.
pub fn str_field(obj: &JsonObject, key: &str) -> Result<String> {
    match obj.get(key) {
        Some(Json::Str(s)) => Ok(s.clone()),
        Some(_) => Err(AcaiError::invalid(format!("field {key:?} must be a string"))),
        None => Err(AcaiError::invalid(format!("missing field {key:?}"))),
    }
}

/// Optional string field (absent is fine; wrong type is not).
pub fn opt_str_field(obj: &JsonObject, key: &str) -> Result<Option<String>> {
    match obj.get(key) {
        Some(Json::Str(s)) => Ok(Some(s.clone())),
        Some(Json::Null) | None => Ok(None),
        Some(_) => Err(AcaiError::invalid(format!("field {key:?} must be a string"))),
    }
}

/// Required numeric field.
pub fn f64_field(obj: &JsonObject, key: &str) -> Result<f64> {
    match obj.get(key) {
        Some(Json::Num(n)) => Ok(*n),
        Some(_) => Err(AcaiError::invalid(format!("field {key:?} must be a number"))),
        None => Err(AcaiError::invalid(format!("missing field {key:?}"))),
    }
}

/// Required non-negative integer field.
pub fn u64_field(obj: &JsonObject, key: &str) -> Result<u64> {
    match obj.get(key) {
        Some(v @ Json::Num(_)) => v
            .as_u64()
            .ok_or_else(|| AcaiError::invalid(format!("field {key:?} must be a non-negative integer"))),
        Some(_) => Err(AcaiError::invalid(format!("field {key:?} must be a number"))),
        None => Err(AcaiError::invalid(format!("missing field {key:?}"))),
    }
}

/// Optional numeric field (absent/null is fine; wrong type is not).
pub fn opt_f64_field(obj: &JsonObject, key: &str) -> Result<Option<f64>> {
    match obj.get(key) {
        Some(Json::Num(n)) => Ok(Some(*n)),
        Some(Json::Null) | None => Ok(None),
        Some(_) => Err(AcaiError::invalid(format!("field {key:?} must be a number"))),
    }
}

/// Optional non-negative integer field (absent/null is fine; wrong
/// type is not).
pub fn opt_u64_field(obj: &JsonObject, key: &str) -> Result<Option<u64>> {
    match obj.get(key) {
        Some(Json::Null) | None => Ok(None),
        Some(v @ Json::Num(_)) => v.as_u64().map(Some).ok_or_else(|| {
            AcaiError::invalid(format!("field {key:?} must be a non-negative integer"))
        }),
        Some(_) => Err(AcaiError::invalid(format!("field {key:?} must be a number"))),
    }
}

/// Optional u32 field — strict type and range.
pub fn opt_u32_field(obj: &JsonObject, key: &str) -> Result<Option<u32>> {
    match obj.get(key) {
        Some(Json::Null) | None => Ok(None),
        Some(v @ Json::Num(_)) => {
            let n = v.as_u64().ok_or_else(|| {
                AcaiError::invalid(format!("field {key:?} must be a non-negative integer"))
            })?;
            u32::try_from(n)
                .map(Some)
                .map_err(|_| AcaiError::invalid(format!("field {key:?} out of range")))
        }
        Some(_) => Err(AcaiError::invalid(format!("field {key:?} must be a number"))),
    }
}

/// Required u32 field — explicit range check, no silent truncation.
pub fn u32_field(obj: &JsonObject, key: &str) -> Result<u32> {
    let v = u64_field(obj, key)?;
    u32::try_from(v)
        .map_err(|_| AcaiError::invalid(format!("field {key:?} out of range (max {})", u32::MAX)))
}

/// Required array field.
pub fn arr_field<'a>(obj: &'a JsonObject, key: &str) -> Result<&'a [Json]> {
    match obj.get(key) {
        Some(Json::Arr(a)) => Ok(a),
        Some(_) => Err(AcaiError::invalid(format!("field {key:?} must be an array"))),
        None => Err(AcaiError::invalid(format!("missing field {key:?}"))),
    }
}

// ---------------------------------------------------------------------
// base64 (file content crosses the JSON wire as standard base64)
// ---------------------------------------------------------------------

const B64_ALPHABET: &[u8; 64] =
    b"ABCDEFGHIJKLMNOPQRSTUVWXYZabcdefghijklmnopqrstuvwxyz0123456789+/";

/// Standard (padded) base64 encoding.
pub fn b64_encode(data: &[u8]) -> String {
    let mut out = String::with_capacity(data.len().div_ceil(3) * 4);
    for chunk in data.chunks(3) {
        let b0 = chunk[0] as u32;
        let b1 = chunk.get(1).copied().unwrap_or(0) as u32;
        let b2 = chunk.get(2).copied().unwrap_or(0) as u32;
        let triple = (b0 << 16) | (b1 << 8) | b2;
        out.push(B64_ALPHABET[(triple >> 18) as usize & 63] as char);
        out.push(B64_ALPHABET[(triple >> 12) as usize & 63] as char);
        out.push(if chunk.len() > 1 {
            B64_ALPHABET[(triple >> 6) as usize & 63] as char
        } else {
            '='
        });
        out.push(if chunk.len() > 2 {
            B64_ALPHABET[triple as usize & 63] as char
        } else {
            '='
        });
    }
    out
}

fn b64_value(c: u8) -> Result<u32> {
    match c {
        b'A'..=b'Z' => Ok((c - b'A') as u32),
        b'a'..=b'z' => Ok((c - b'a') as u32 + 26),
        b'0'..=b'9' => Ok((c - b'0') as u32 + 52),
        b'+' => Ok(62),
        b'/' => Ok(63),
        _ => Err(AcaiError::invalid(format!(
            "bad base64 character {:?}",
            c as char
        ))),
    }
}

/// Standard (padded) base64 decoding.
pub fn b64_decode(s: &str) -> Result<Vec<u8>> {
    let bytes = s.as_bytes();
    if bytes.len() % 4 != 0 {
        return Err(AcaiError::invalid("base64 length must be a multiple of 4"));
    }
    let n_chunks = bytes.len() / 4;
    let mut out = Vec::with_capacity(n_chunks * 3);
    for (ci, chunk) in bytes.chunks(4).enumerate() {
        let pad = chunk.iter().filter(|&&c| c == b'=').count();
        if pad > 0 && ci + 1 != n_chunks {
            return Err(AcaiError::invalid("base64 padding before the final chunk"));
        }
        if pad > 2 || (pad > 0 && (chunk[2] == b'=') != (pad == 2)) {
            return Err(AcaiError::invalid("bad base64 padding"));
        }
        if chunk[..4 - pad].iter().any(|&c| c == b'=') {
            return Err(AcaiError::invalid("bad base64 padding"));
        }
        let mut triple = 0u32;
        for &c in &chunk[..4 - pad] {
            triple = (triple << 6) | b64_value(c)?;
        }
        triple <<= 6 * pad as u32;
        out.push((triple >> 16) as u8);
        if pad < 2 {
            out.push((triple >> 8) as u8);
        }
        if pad < 1 {
            out.push(triple as u8);
        }
    }
    Ok(out)
}

// ---------------------------------------------------------------------
// pagination
// ---------------------------------------------------------------------

/// Hard cap on one page of any list endpoint.
pub const MAX_PAGE_LIMIT: usize = 1000;
/// Default page size when `?limit=` is absent.
pub const DEFAULT_PAGE_LIMIT: usize = 100;

/// Cursor-pagination request: `?limit=&after=`.
#[derive(Debug, Clone)]
pub struct PageReq {
    pub limit: usize,
    /// Opaque cursor: the `next` value of the previous page.
    pub after: Option<String>,
}

impl Default for PageReq {
    fn default() -> Self {
        Self {
            limit: DEFAULT_PAGE_LIMIT,
            after: None,
        }
    }
}

impl PageReq {
    /// Parse from a query string (validated via [`PageReq::checked`]).
    pub fn from_query(q: &Query) -> Result<PageReq> {
        let limit = match q.get("limit") {
            None => DEFAULT_PAGE_LIMIT,
            Some(raw) => raw
                .parse()
                .map_err(|_| AcaiError::invalid(format!("bad limit {raw:?}")))?,
        };
        PageReq {
            limit,
            after: q.get("after").map(String::from),
        }
        .checked()
    }

    /// The shared page invariants BOTH clients enforce, so the
    /// in-process and wire paths agree: `limit == 0` is a 400,
    /// `limit > MAX_PAGE_LIMIT` is clamped.
    pub fn checked(&self) -> Result<PageReq> {
        if self.limit == 0 {
            return Err(AcaiError::invalid("limit must be >= 1"));
        }
        Ok(PageReq {
            limit: self.limit.min(MAX_PAGE_LIMIT),
            after: self.after.clone(),
        })
    }
}

/// One page of results plus the cursor for the next.
#[derive(Debug, Clone)]
pub struct Page<T> {
    pub items: Vec<T>,
    /// Pass back as `?after=` to continue; `None` means exhausted.
    pub next: Option<String>,
}

/// Apply cursor pagination to `items`, which must be ascending in
/// `key` (cursors compare lexicographically — zero-pad numeric keys).
pub fn cut_page<T>(items: Vec<T>, page: &PageReq, key: impl Fn(&T) -> String) -> Page<T> {
    let mut out = Vec::new();
    let mut last_key: Option<String> = None;
    let mut more = false;
    for item in items {
        let k = key(&item);
        if let Some(after) = &page.after {
            if k.as_str() <= after.as_str() {
                continue;
            }
        }
        if out.len() == page.limit {
            more = true;
            break;
        }
        last_key = Some(k);
        out.push(item);
    }
    Page {
        items: out,
        next: if more { last_key } else { None },
    }
}

/// Encode a page as `{"items": [...], "next": cursor-or-null}`.
pub fn page_json(items: Vec<Json>, next: &Option<String>) -> Json {
    Json::obj()
        .field("items", Json::Arr(items))
        .field(
            "next",
            match next {
                Some(c) => Json::from(c.as_str()),
                None => Json::Null,
            },
        )
        .build()
}

/// Decode a page, mapping each item through `item`.
pub fn page_from_json<T>(
    v: &Json,
    item: impl Fn(&Json) -> Result<T>,
) -> Result<Page<T>> {
    let obj = as_object(v)?;
    let raw = arr_field(obj, "items")?;
    let mut items = Vec::with_capacity(raw.len());
    for it in raw {
        items.push(item(it)?);
    }
    let next = match obj.get("next") {
        Some(Json::Str(s)) => Some(s.clone()),
        _ => None,
    };
    Ok(Page { items, next })
}

// ---------------------------------------------------------------------
// files + file sets
// ---------------------------------------------------------------------

/// A (path-or-name, version) listing entry.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct FileEntry {
    pub path: String,
    pub version: Version,
}

impl FileEntry {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .field("path", self.path.as_str())
            .field("version", self.version)
            .build()
    }

    pub fn from_json(v: &Json) -> Result<FileEntry> {
        let obj = as_object(v)?;
        Ok(FileEntry {
            path: str_field(obj, "path")?,
            version: u32_field(obj, "version")?,
        })
    }
}

/// Chunk-manifest view of one file version
/// (`GET /v1/files/{path}/stat`): the content-addressed decomposition
/// the data plane stores the body as.
#[derive(Debug, Clone, PartialEq)]
pub struct FileManifest {
    pub path: String,
    pub version: Version,
    /// Logical size in bytes.
    pub size: u64,
    /// Chunking granularity the manifest was built with.
    pub chunk_size: u64,
    /// Ordered chunk ids (each id embeds its own length).
    pub chunks: Vec<String>,
}

impl FileManifest {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .field("path", self.path.as_str())
            .field("version", self.version)
            .field("size", self.size)
            .field("chunk_size", self.chunk_size)
            .field(
                "chunks",
                Json::Arr(self.chunks.iter().map(|c| Json::from(c.as_str())).collect()),
            )
            .build()
    }

    pub fn from_json(v: &Json) -> Result<FileManifest> {
        let obj = as_object(v)?;
        check_fields(obj, &["path", "version", "size", "chunk_size", "chunks"])?;
        let chunks = arr_field(obj, "chunks")?
            .iter()
            .map(|c| {
                c.as_str()
                    .map(String::from)
                    .ok_or_else(|| AcaiError::invalid("chunk ids must be strings"))
            })
            .collect::<Result<_>>()?;
        Ok(FileManifest {
            path: str_field(obj, "path")?,
            version: u32_field(obj, "version")?,
            size: u64_field(obj, "size")?,
            chunk_size: u64_field(obj, "chunk_size")?,
            chunks,
        })
    }
}

/// The data-plane counter block of `GET /v1/metrics`: dedup counters
/// from the chunk store plus transfer/cache counters from the cluster.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DataPlaneMetrics {
    /// Bytes ingested (pre-dedup).
    pub logical_bytes: u64,
    /// Bytes written as fresh chunks (post-dedup).
    pub stored_bytes: u64,
    /// Bytes an ingest skipped because the chunk already existed.
    pub deduped_bytes: u64,
    /// Chunk-level dedup hits.
    pub dedup_hits: u64,
    /// Live chunk rows.
    pub chunks: u64,
    /// Input bytes served from node-local chunk caches at launch.
    pub cache_hit_bytes: u64,
    /// Input bytes pulled cold over the simulated network.
    pub cold_transfer_bytes: u64,
    /// Simulated transfer time spent pulling cold bytes.
    pub transfer_secs: f64,
}

impl DataPlaneMetrics {
    /// logical / stored (1.0 when nothing is stored yet).
    pub fn dedup_ratio(&self) -> f64 {
        if self.stored_bytes == 0 {
            1.0
        } else {
            self.logical_bytes as f64 / self.stored_bytes as f64
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .field("logical_bytes", self.logical_bytes)
            .field("stored_bytes", self.stored_bytes)
            .field("deduped_bytes", self.deduped_bytes)
            .field("dedup_hits", self.dedup_hits)
            .field("chunks", self.chunks)
            .field("dedup_ratio", self.dedup_ratio())
            .field("cache_hit_bytes", self.cache_hit_bytes)
            .field("cold_transfer_bytes", self.cold_transfer_bytes)
            .field("transfer_secs", self.transfer_secs)
            .build()
    }

    pub fn from_json(v: &Json) -> Result<DataPlaneMetrics> {
        let obj = as_object(v)?;
        Ok(DataPlaneMetrics {
            logical_bytes: u64_field(obj, "logical_bytes")?,
            stored_bytes: u64_field(obj, "stored_bytes")?,
            deduped_bytes: u64_field(obj, "deduped_bytes")?,
            dedup_hits: u64_field(obj, "dedup_hits")?,
            chunks: u64_field(obj, "chunks")?,
            cache_hit_bytes: u64_field(obj, "cache_hit_bytes")?,
            cold_transfer_bytes: u64_field(obj, "cold_transfer_bytes")?,
            transfer_secs: f64_field(obj, "transfer_secs")?,
        })
    }
}

// ---------------------------------------------------------------------
// datalake time travel (commits, branches, diffs)
// ---------------------------------------------------------------------

/// Wire summary of one datalake commit (`GET /v1/commits/{id}`): the
/// snapshot identity and its span, not the per-file manifest table
/// (that stays server-side; `diff` is the chunk-level view of it).
#[derive(Debug, Clone, PartialEq)]
pub struct CommitInfo {
    /// `"commit-N"`.
    pub id: String,
    pub message: String,
    pub created_at: f64,
    /// Live paths the snapshot pins.
    pub files: u64,
    /// Total logical bytes across those paths.
    pub bytes: u64,
}

impl CommitInfo {
    pub fn from_commit(c: &Commit) -> CommitInfo {
        CommitInfo {
            id: c.id.to_string(),
            message: c.message.clone(),
            created_at: c.created,
            files: c.files.len() as u64,
            bytes: c.bytes(),
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .field("commit", self.id.as_str())
            .field("message", self.message.as_str())
            .field("created_at", self.created_at)
            .field("files", self.files)
            .field("bytes", self.bytes)
            .build()
    }

    pub fn from_json(v: &Json) -> Result<CommitInfo> {
        let obj = as_object(v)?;
        check_fields(obj, &["commit", "message", "created_at", "files", "bytes"])?;
        Ok(CommitInfo {
            id: str_field(obj, "commit")?,
            message: str_field(obj, "message")?,
            created_at: f64_field(obj, "created_at")?,
            files: u64_field(obj, "files")?,
            bytes: u64_field(obj, "bytes")?,
        })
    }
}

/// Wire view of one branch (`GET /v1/branches/{name}`).
#[derive(Debug, Clone, PartialEq)]
pub struct BranchInfo {
    pub name: String,
    /// The commit the ref points at (`"commit-N"`).
    pub commit: String,
    pub created_at: f64,
}

impl BranchInfo {
    pub fn from_branch(b: &Branch) -> BranchInfo {
        BranchInfo {
            name: b.name.clone(),
            commit: b.commit.to_string(),
            created_at: b.created,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .field("name", self.name.as_str())
            .field("commit", self.commit.as_str())
            .field("created_at", self.created_at)
            .build()
    }

    pub fn from_json(v: &Json) -> Result<BranchInfo> {
        let obj = as_object(v)?;
        check_fields(obj, &["name", "commit", "created_at"])?;
        Ok(BranchInfo {
            name: str_field(obj, "name")?,
            commit: str_field(obj, "commit")?,
            created_at: f64_field(obj, "created_at")?,
        })
    }
}

fn diff_entry_to_json(e: &DiffEntry) -> Json {
    Json::obj()
        .field("path", e.path.as_str())
        .field("bytes", e.bytes)
        .build()
}

fn diff_entry_from_json(v: &Json) -> Result<DiffEntry> {
    let obj = as_object(v)?;
    check_fields(obj, &["path", "bytes"])?;
    Ok(DiffEntry {
        path: str_field(obj, "path")?,
        bytes: u64_field(obj, "bytes")?,
    })
}

fn changed_entry_to_json(e: &ChangedEntry) -> Json {
    Json::obj()
        .field("path", e.path.as_str())
        .field("bytes_added", e.bytes_added)
        .field("bytes_removed", e.bytes_removed)
        .field("chunks_added", e.chunks_added)
        .field("chunks_removed", e.chunks_removed)
        .field("changed_bytes", e.changed_bytes())
        .build()
}

fn changed_entry_from_json(v: &Json) -> Result<ChangedEntry> {
    let obj = as_object(v)?;
    check_fields(
        obj,
        &[
            "path",
            "bytes_added",
            "bytes_removed",
            "chunks_added",
            "chunks_removed",
            "changed_bytes",
        ],
    )?;
    let entry = ChangedEntry {
        path: str_field(obj, "path")?,
        bytes_added: u64_field(obj, "bytes_added")?,
        bytes_removed: u64_field(obj, "bytes_removed")?,
        chunks_added: u64_field(obj, "chunks_added")?,
        chunks_removed: u64_field(obj, "chunks_removed")?,
    };
    // derived on the wire for readability; must agree with the parts
    if u64_field(obj, "changed_bytes")? != entry.changed_bytes() {
        return Err(AcaiError::invalid(
            "changed_bytes must equal bytes_added + bytes_removed",
        ));
    }
    Ok(entry)
}

/// `GET /v1/commits/{a}/diff/{b}` — chunk-level comparison, per path.
pub fn commit_diff_to_json(d: &CommitDiff) -> Json {
    Json::obj()
        .field("added", Json::Arr(d.added.iter().map(diff_entry_to_json).collect()))
        .field(
            "removed",
            Json::Arr(d.removed.iter().map(diff_entry_to_json).collect()),
        )
        .field(
            "changed",
            Json::Arr(d.changed.iter().map(changed_entry_to_json).collect()),
        )
        .build()
}

pub fn commit_diff_from_json(v: &Json) -> Result<CommitDiff> {
    let obj = as_object(v)?;
    check_fields(obj, &["added", "removed", "changed"])?;
    Ok(CommitDiff {
        added: arr_field(obj, "added")?
            .iter()
            .map(diff_entry_from_json)
            .collect::<Result<_>>()?,
        removed: arr_field(obj, "removed")?
            .iter()
            .map(diff_entry_from_json)
            .collect::<Result<_>>()?,
        changed: arr_field(obj, "changed")?
            .iter()
            .map(changed_entry_from_json)
            .collect::<Result<_>>()?,
    })
}

/// What `POST /v1/branches/{name}/rollback` touched.
#[derive(Debug, Clone, PartialEq)]
pub struct RollbackSummary {
    pub branch: String,
    /// The commit the branch resolved to (`"commit-N"`).
    pub commit: String,
    /// File rows re-written from the snapshot.
    pub restored: u64,
    /// `latest` pointers moved back onto snapshot versions.
    pub repointed: u64,
    /// Paths born after the commit, removed from the live table.
    pub removed: u64,
}

impl RollbackSummary {
    pub fn from_report(branch: &str, r: &RollbackReport) -> RollbackSummary {
        RollbackSummary {
            branch: branch.to_string(),
            commit: r.commit.to_string(),
            restored: r.restored,
            repointed: r.repointed,
            removed: r.removed,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .field("branch", self.branch.as_str())
            .field("commit", self.commit.as_str())
            .field("restored", self.restored)
            .field("repointed", self.repointed)
            .field("removed", self.removed)
            .build()
    }

    pub fn from_json(v: &Json) -> Result<RollbackSummary> {
        let obj = as_object(v)?;
        check_fields(obj, &["branch", "commit", "restored", "repointed", "removed"])?;
        Ok(RollbackSummary {
            branch: str_field(obj, "branch")?,
            commit: str_field(obj, "commit")?,
            restored: u64_field(obj, "restored")?,
            repointed: u64_field(obj, "repointed")?,
            removed: u64_field(obj, "removed")?,
        })
    }
}

/// `POST /v1/gc/sweep` — what one sweep deleted and reclaimed.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GcSweepReport {
    /// File versions (no file set or commit referenced them) deleted.
    pub unreferenced_files: u64,
    /// Logical bytes those versions spanned.
    pub reclaimable_bytes: u64,
    /// Zero-refcount chunks the reclaim pass deleted.
    pub reclaimed_chunks: u64,
    /// Stored bytes that reclaim freed.
    pub reclaimed_chunk_bytes: u64,
}

impl GcSweepReport {
    pub fn from_report(r: &GcReport) -> GcSweepReport {
        GcSweepReport {
            unreferenced_files: r.unreferenced.len() as u64,
            reclaimable_bytes: r.reclaimable_bytes as u64,
            reclaimed_chunks: r.reclaimed_chunks,
            reclaimed_chunk_bytes: r.reclaimed_chunk_bytes,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .field("unreferenced_files", self.unreferenced_files)
            .field("reclaimable_bytes", self.reclaimable_bytes)
            .field("reclaimed_chunks", self.reclaimed_chunks)
            .field("reclaimed_chunk_bytes", self.reclaimed_chunk_bytes)
            .build()
    }

    pub fn from_json(v: &Json) -> Result<GcSweepReport> {
        let obj = as_object(v)?;
        check_fields(
            obj,
            &[
                "unreferenced_files",
                "reclaimable_bytes",
                "reclaimed_chunks",
                "reclaimed_chunk_bytes",
            ],
        )?;
        Ok(GcSweepReport {
            unreferenced_files: u64_field(obj, "unreferenced_files")?,
            reclaimable_bytes: u64_field(obj, "reclaimable_bytes")?,
            reclaimed_chunks: u64_field(obj, "reclaimed_chunks")?,
            reclaimed_chunk_bytes: u64_field(obj, "reclaimed_chunk_bytes")?,
        })
    }
}

// ---------------------------------------------------------------------
// tenancy
// ---------------------------------------------------------------------

/// One project's API-edge usage + billing counters
/// (`GET /v1/tenant`): what the tenant admission layer has counted and
/// what the [`crate::pricing`] request/byte anchors price it at.
#[derive(Debug, Clone, PartialEq)]
pub struct TenantUsageReport {
    pub project: String,
    /// Admitted API calls.
    pub requests: u64,
    /// Request payload bytes admitted.
    pub request_bytes: u64,
    /// Response payload bytes served.
    pub response_bytes: u64,
    /// Calls bounced with 429 by the rate limiter (retryable).
    pub throttled: u64,
    /// Calls rejected for quota exhaustion (terminal).
    pub rejected: u64,
    /// Dollar cost of the admitted usage.
    pub api_cost: f64,
}

impl TenantUsageReport {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .field("project", self.project.clone())
            .field("requests", self.requests)
            .field("request_bytes", self.request_bytes)
            .field("response_bytes", self.response_bytes)
            .field("throttled", self.throttled)
            .field("rejected", self.rejected)
            .field("api_cost", self.api_cost)
            .build()
    }

    pub fn from_json(v: &Json) -> Result<TenantUsageReport> {
        let obj = as_object(v)?;
        check_fields(
            obj,
            &[
                "project",
                "requests",
                "request_bytes",
                "response_bytes",
                "throttled",
                "rejected",
                "api_cost",
            ],
        )?;
        Ok(TenantUsageReport {
            project: str_field(obj, "project")?,
            requests: u64_field(obj, "requests")?,
            request_bytes: u64_field(obj, "request_bytes")?,
            response_bytes: u64_field(obj, "response_bytes")?,
            throttled: u64_field(obj, "throttled")?,
            rejected: u64_field(obj, "rejected")?,
            api_cost: f64_field(obj, "api_cost")?,
        })
    }
}

// ---------------------------------------------------------------------
// jobs
// ---------------------------------------------------------------------

/// Submission payload (`POST /v1/jobs`).  `input_fileset` (a job may
/// take no input), `pool` (a placement constraint; `None` = any
/// pool), `data_commit` (pin input resolution to a datalake commit;
/// `None` = latest), `priority` (`low|normal|high`, default `normal`)
/// and `gang` (all-or-nothing replica count, default 1) are the only
/// optional fields; everything else is required, so a typo'd or
/// missing field fails loudly instead of submitting a half-empty job.
pub fn job_request_from_json(v: &Json) -> Result<JobRequest> {
    let obj = as_object(v)?;
    check_fields(
        obj,
        &[
            "name", "command", "input_fileset", "output_fileset", "vcpus", "mem_mb", "pool",
            "data_commit", "priority", "gang",
        ],
    )?;
    Ok(JobRequest {
        name: str_field(obj, "name")?,
        command: str_field(obj, "command")?,
        input_fileset: opt_str_field(obj, "input_fileset")?.unwrap_or_default(),
        output_fileset: str_field(obj, "output_fileset")?,
        resources: ResourceConfig::new(f64_field(obj, "vcpus")?, u32_field(obj, "mem_mb")?),
        pool: opt_str_field(obj, "pool")?,
        data_commit: opt_str_field(obj, "data_commit")?,
        priority: match opt_str_field(obj, "priority")? {
            Some(s) => Priority::parse(&s)?,
            None => Priority::Normal,
        },
        gang: opt_u64_field(obj, "gang")?
            .map(|g| {
                u32::try_from(g)
                    .map_err(|_| AcaiError::invalid(format!("gang {g} out of range")))
            })
            .transpose()?
            .unwrap_or(1),
    })
}

pub fn job_request_to_json(r: &JobRequest) -> Json {
    let mut b = Json::obj()
        .field("name", r.name.as_str())
        .field("command", r.command.as_str())
        .field("input_fileset", r.input_fileset.as_str())
        .field("output_fileset", r.output_fileset.as_str())
        .field("vcpus", r.resources.vcpus)
        .field("mem_mb", r.resources.mem_mb);
    if let Some(pool) = &r.pool {
        b = b.field("pool", pool.as_str());
    }
    if let Some(commit) = &r.data_commit {
        b = b.field("data_commit", commit.as_str());
    }
    // defaults stay off the wire so pre-fair-share payloads round-trip
    if r.priority != Priority::Normal {
        b = b.field("priority", r.priority.as_str());
    }
    if r.gang > 1 {
        b = b.field("gang", r.gang);
    }
    b.build()
}

/// Job status as seen through the API (the project-public subset of
/// [`JobRecord`] — internal ids like the container stay inside).
#[derive(Debug, Clone)]
pub struct JobStatus {
    pub id: JobId,
    pub name: String,
    /// Lifecycle state string (`queued`, `running`, `finished`, ...).
    pub state: String,
    pub command: String,
    pub submitted_at: f64,
    pub runtime_secs: Option<f64>,
    pub cost: Option<f64>,
    pub output_version: Option<Version>,
    pub error: Option<String>,
    /// Spot revocations this job survived (0 for on-demand runs).
    pub preemptions: u64,
    /// Simulated cold-input transfer seconds folded into
    /// `runtime_secs` (absent when every input byte was node-local).
    pub transfer_secs: Option<f64>,
    /// Scheduling priority (`normal` when unset).
    pub priority: Priority,
    /// All-or-nothing replica count (1 = single container).
    pub gang: u32,
}

impl JobStatus {
    pub fn terminal(&self) -> bool {
        matches!(self.state.as_str(), "finished" | "failed" | "killed")
    }

    pub fn from_record(r: &JobRecord) -> JobStatus {
        JobStatus {
            id: r.id,
            name: r.spec.name.clone(),
            state: r.state.as_str().to_string(),
            command: r.spec.command.clone(),
            submitted_at: r.submitted_at,
            runtime_secs: r.runtime_secs,
            cost: r.cost,
            output_version: r.output_version,
            error: r.error.clone(),
            preemptions: r.preemptions,
            // normalized so the wire (which omits zero) and the
            // in-process path agree: zero transfer reads as absent
            transfer_secs: r.transfer_secs.filter(|t| *t > 0.0),
            priority: r.spec.priority,
            gang: r.spec.gang.max(1),
        }
    }

    pub fn to_json(&self) -> Json {
        let mut b = Json::obj()
            .field("job", self.id.to_string())
            .field("name", self.name.as_str())
            .field("state", self.state.as_str())
            .field("command", self.command.as_str())
            .field("submitted_at", self.submitted_at);
        if let Some(t) = self.runtime_secs {
            b = b.field("runtime_secs", t);
        }
        if let Some(c) = self.cost {
            b = b.field("cost", c);
        }
        if let Some(v) = self.output_version {
            b = b.field("output_version", v);
        }
        if let Some(e) = &self.error {
            b = b.field("error", e.as_str());
        }
        if self.preemptions > 0 {
            b = b.field("preemptions", self.preemptions);
        }
        if let Some(t) = self.transfer_secs {
            b = b.field("transfer_secs", t);
        }
        if self.priority != Priority::Normal {
            b = b.field("priority", self.priority.as_str());
        }
        if self.gang > 1 {
            b = b.field("gang", self.gang);
        }
        b.build()
    }

    pub fn from_json(v: &Json) -> Result<JobStatus> {
        let obj = as_object(v)?;
        Ok(JobStatus {
            id: str_field(obj, "job")?.parse()?,
            name: str_field(obj, "name")?,
            state: str_field(obj, "state")?,
            command: str_field(obj, "command")?,
            submitted_at: f64_field(obj, "submitted_at")?,
            runtime_secs: opt_f64_field(obj, "runtime_secs")?,
            cost: opt_f64_field(obj, "cost")?,
            output_version: opt_u32_field(obj, "output_version")?,
            error: opt_str_field(obj, "error")?,
            preemptions: opt_u64_field(obj, "preemptions")?.unwrap_or(0),
            transfer_secs: opt_f64_field(obj, "transfer_secs")?,
            priority: match opt_str_field(obj, "priority")? {
                Some(s) => Priority::parse(&s)?,
                None => Priority::Normal,
            },
            gang: opt_u64_field(obj, "gang")?.unwrap_or(1) as u32,
        })
    }
}

// ---------------------------------------------------------------------
// scheduler metrics
// ---------------------------------------------------------------------

/// The `scheduler` block of `GET /v1/metrics`: monotonic decision
/// counters plus every project's live weighted-DRF share.
pub fn scheduler_metrics_to_json(
    counters: &SchedulerCounters,
    shares: &[ProjectShare],
) -> Json {
    Json::obj()
        .field("decisions", counters.decisions)
        .field("launched", counters.launched)
        .field("requeues", counters.requeues)
        .field("evictions", counters.evictions)
        .field("last_pump_decisions", counters.last_pump_decisions)
        .field("max_pump_decisions", counters.max_pump_decisions)
        .field(
            "projects",
            Json::Arr(
                shares
                    .iter()
                    .map(|s| {
                        Json::obj()
                            .field("project", s.project.to_string())
                            .field("weight", s.weight)
                            .field("share", s.share)
                            .field("queued", s.queued as u64)
                            .field("active", s.active as u64)
                            .build()
                    })
                    .collect(),
            ),
        )
        .build()
}

/// One slice of a job log (`GET /v1/jobs/{id}/logs?offset=`).
#[derive(Debug, Clone)]
pub struct LogChunk {
    pub lines: Vec<String>,
    /// Pass back as `?offset=` to read only what is new.
    pub next_offset: usize,
}

impl LogChunk {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .field(
                "lines",
                Json::Arr(self.lines.iter().map(|l| Json::from(l.as_str())).collect()),
            )
            .field("next_offset", self.next_offset)
            .build()
    }

    pub fn from_json(v: &Json) -> Result<LogChunk> {
        let obj = as_object(v)?;
        let lines = arr_field(obj, "lines")?
            .iter()
            .map(|l| {
                l.as_str()
                    .map(String::from)
                    .ok_or_else(|| AcaiError::invalid("log lines must be strings"))
            })
            .collect::<Result<_>>()?;
        Ok(LogChunk {
            lines,
            next_offset: u64_field(obj, "next_offset")? as usize,
        })
    }
}

// ---------------------------------------------------------------------
// metadata kinds + query clauses
// ---------------------------------------------------------------------

/// Strict artifact-kind parsing: the only accepted spellings are the
/// plural collection names.  Anything else is a 400 — never a silent
/// fallback to jobs.
pub fn kind_from_str(s: &str) -> Result<ArtifactKind> {
    match s {
        "jobs" => Ok(ArtifactKind::Job),
        "files" => Ok(ArtifactKind::File),
        "filesets" => Ok(ArtifactKind::FileSet),
        other => Err(AcaiError::invalid(format!(
            "unknown artifact kind {other:?} (expected jobs|files|filesets)"
        ))),
    }
}

pub fn kind_to_str(kind: ArtifactKind) -> &'static str {
    match kind {
        ArtifactKind::Job => "jobs",
        ArtifactKind::File => "files",
        ArtifactKind::FileSet => "filesets",
    }
}

/// Shared tag validation — the single source of truth for both the
/// in-process client and the wire route: tags must be a non-empty set
/// of scalar (indexable) values.
pub fn validate_tags(fields: &[(String, Json)]) -> Result<()> {
    if fields.is_empty() {
        return Err(AcaiError::invalid("tags need at least one field"));
    }
    for (key, value) in fields {
        if key == crate::docstore::VERSION_FIELD {
            // the optimistic-concurrency version counter is platform
            // managed; a user tag overwriting it would break every
            // subsequent expected_version guard on the document
            return Err(AcaiError::invalid(
                "tag key \"version\" is reserved for optimistic concurrency",
            ));
        }
        if matches!(value, Json::Arr(_) | Json::Obj(_)) {
            return Err(AcaiError::invalid(format!(
                "tag {key:?} must be a scalar (indexable) value"
            )));
        }
    }
    Ok(())
}

fn index_key_to_json(k: &IndexKey) -> Json {
    match k {
        IndexKey::Null => Json::Null,
        IndexKey::Bool(b) => Json::Bool(*b),
        IndexKey::Num(n) => Json::Num(*n),
        IndexKey::Str(s) => Json::Str(s.clone()),
    }
}

fn index_key_from_json(v: &Json) -> Result<Option<IndexKey>> {
    if v.is_null() {
        return Ok(None);
    }
    IndexKey::of(v)
        .map(Some)
        .ok_or_else(|| AcaiError::invalid("range bounds must be scalars"))
}

/// Query-clause wire codec (`POST /v1/metadata/{kind}/query`).
pub fn clause_to_json(c: &Clause) -> Json {
    match c {
        Clause::Eq(key, v) => Json::obj()
            .field("op", "eq")
            .field("key", key.as_str())
            .field("value", v.clone())
            .build(),
        Clause::Range { key, lo, hi } => Json::obj()
            .field("op", "range")
            .field("key", key.as_str())
            .field("lo", lo.as_ref().map(index_key_to_json).unwrap_or(Json::Null))
            .field("hi", hi.as_ref().map(index_key_to_json).unwrap_or(Json::Null))
            .build(),
        Clause::Max(key) => Json::obj().field("op", "max").field("key", key.as_str()).build(),
        Clause::Min(key) => Json::obj().field("op", "min").field("key", key.as_str()).build(),
    }
}

pub fn clause_from_json(v: &Json) -> Result<Clause> {
    let obj = as_object(v)?;
    check_fields(obj, &["op", "key", "value", "lo", "hi"])?;
    let op = str_field(obj, "op")?;
    let key = str_field(obj, "key")?;
    match op.as_str() {
        "eq" => {
            let value = obj
                .get("value")
                .ok_or_else(|| AcaiError::invalid("eq clause needs \"value\""))?;
            Ok(Clause::Eq(key, value.clone()))
        }
        "range" => {
            let lo = index_key_from_json(obj.get("lo").unwrap_or(&Json::Null))?;
            let hi = index_key_from_json(obj.get("hi").unwrap_or(&Json::Null))?;
            if lo.is_none() && hi.is_none() {
                return Err(AcaiError::invalid("range clause needs lo and/or hi"));
            }
            Ok(Clause::Range { key, lo, hi })
        }
        "max" => Ok(Clause::Max(key)),
        "min" => Ok(Clause::Min(key)),
        other => Err(AcaiError::invalid(format!("unknown clause op {other:?}"))),
    }
}

// ---------------------------------------------------------------------
// experiments (hyperparameter sweeps)
// ---------------------------------------------------------------------

/// Submission payload (`POST /v1/experiments`).  `strategy` is `grid`
/// (no extra fields allowed) or `random` (requires `samples`, takes an
/// optional `seed`); `profile` + `objective` opt into per-trial
/// auto-provisioning and must come together.
pub fn experiment_spec_from_json(v: &Json) -> Result<ExperimentSpec> {
    let obj = as_object(v)?;
    check_fields(
        obj,
        &[
            "name", "template", "input_fileset", "strategy", "samples", "seed", "vcpus",
            "mem_mb", "profile", "objective", "pool", "data_commit",
        ],
    )?;
    let strategy = match str_field(obj, "strategy")?.as_str() {
        "grid" => {
            if obj.contains_key("samples") || obj.contains_key("seed") {
                return Err(AcaiError::invalid(
                    "grid strategy takes no \"samples\"/\"seed\"",
                ));
            }
            SweepStrategy::Grid
        }
        "random" => SweepStrategy::Random {
            samples: u64_field(obj, "samples")? as usize,
            seed: match obj.get("seed") {
                None | Some(Json::Null) => 0xACA1,
                Some(_) => u64_field(obj, "seed")?,
            },
        },
        other => {
            return Err(AcaiError::invalid(format!(
                "unknown strategy {other:?} (expected grid|random)"
            )))
        }
    };
    let objective = match obj.get("objective") {
        None | Some(Json::Null) => None,
        Some(v) => Some(objective_from_json(v)?),
    };
    Ok(ExperimentSpec {
        name: str_field(obj, "name")?,
        template: str_field(obj, "template")?,
        input_fileset: opt_str_field(obj, "input_fileset")?.unwrap_or_default(),
        strategy,
        resources: ResourceConfig::new(f64_field(obj, "vcpus")?, u32_field(obj, "mem_mb")?),
        profile: opt_str_field(obj, "profile")?,
        objective,
        pool: opt_str_field(obj, "pool")?,
        data_commit: opt_str_field(obj, "data_commit")?,
    })
}

pub fn experiment_spec_to_json(s: &ExperimentSpec) -> Json {
    let mut b = Json::obj()
        .field("name", s.name.as_str())
        .field("template", s.template.as_str())
        .field("input_fileset", s.input_fileset.as_str())
        .field("strategy", s.strategy.as_str())
        .field("vcpus", s.resources.vcpus)
        .field("mem_mb", s.resources.mem_mb);
    if let SweepStrategy::Random { samples, seed } = s.strategy {
        b = b.field("samples", samples).field("seed", seed);
    }
    if let Some(p) = &s.profile {
        b = b.field("profile", p.as_str());
    }
    if let Some(o) = &s.objective {
        b = b.field("objective", objective_to_json(o));
    }
    if let Some(pool) = &s.pool {
        b = b.field("pool", pool.as_str());
    }
    if let Some(commit) = &s.data_commit {
        b = b.field("data_commit", commit.as_str());
    }
    b.build()
}

pub fn experiment_status_to_json(s: &ExperimentStatus) -> Json {
    Json::obj()
        .field("experiment", s.id.to_string())
        .field("name", s.name.as_str())
        .field("state", s.state.as_str())
        .field("trials", s.trials)
        .field("finished", s.finished)
        .field("failed", s.failed)
        .field("created_at", s.created_at)
        .build()
}

pub fn experiment_status_from_json(v: &Json) -> Result<ExperimentStatus> {
    let obj = as_object(v)?;
    Ok(ExperimentStatus {
        id: str_field(obj, "experiment")?.parse()?,
        name: str_field(obj, "name")?,
        state: str_field(obj, "state")?,
        trials: u64_field(obj, "trials")? as usize,
        finished: u64_field(obj, "finished")? as usize,
        failed: u64_field(obj, "failed")? as usize,
        created_at: f64_field(obj, "created_at")?,
    })
}

fn f64_pairs_to_json(pairs: &[(String, f64)]) -> Json {
    let mut obj = JsonObject::new();
    for (k, v) in pairs {
        obj.set(k.clone(), *v);
    }
    Json::Obj(obj)
}

fn f64_pairs_from_json(obj: &JsonObject, key: &str) -> Result<Vec<(String, f64)>> {
    match obj.get(key) {
        Some(Json::Obj(o)) => o
            .iter()
            .map(|(k, v)| {
                v.as_f64().map(|n| (k.to_string(), n)).ok_or_else(|| {
                    AcaiError::invalid(format!("field {key:?} values must be numbers"))
                })
            })
            .collect(),
        Some(_) => Err(AcaiError::invalid(format!("field {key:?} must be an object"))),
        None => Err(AcaiError::invalid(format!("missing field {key:?}"))),
    }
}

pub fn trial_status_to_json(t: &TrialStatus) -> Json {
    let mut b = Json::obj()
        .field("experiment", t.experiment.to_string())
        .field("index", t.index)
        .field("name", t.name.as_str())
        .field("command", t.command.as_str())
        .field("args", f64_pairs_to_json(&t.args))
        .field("vcpus", t.resources.vcpus)
        .field("mem_mb", t.resources.mem_mb)
        .field("state", t.state.as_str())
        .field("metrics", f64_pairs_to_json(&t.metrics));
    if let Some(j) = t.job {
        b = b.field("job", j.to_string());
        // derived, never stored: the job id doubles as the trial's
        // trace key (`GET /v1/trace/jobs/{id}`)
        b = b.field("trace", j.to_string());
    }
    if let Some(v) = t.predicted_runtime {
        b = b.field("predicted_runtime", v);
    }
    if let Some(v) = t.predicted_cost {
        b = b.field("predicted_cost", v);
    }
    if let Some(v) = t.runtime_secs {
        b = b.field("runtime_secs", v);
    }
    if let Some(c) = t.cost {
        b = b.field("cost", c);
    }
    if let Some(o) = &t.output {
        b = b.field("output", o.as_str());
    }
    if let Some(e) = &t.error {
        b = b.field("error", e.as_str());
    }
    b.build()
}

pub fn trial_status_from_json(v: &Json) -> Result<TrialStatus> {
    let obj = as_object(v)?;
    let job = match opt_str_field(obj, "job")? {
        Some(s) => Some(s.parse::<JobId>()?),
        None => None,
    };
    Ok(TrialStatus {
        experiment: str_field(obj, "experiment")?.parse::<ExperimentId>()?,
        index: u64_field(obj, "index")? as usize,
        job,
        name: str_field(obj, "name")?,
        command: str_field(obj, "command")?,
        args: f64_pairs_from_json(obj, "args")?,
        resources: ResourceConfig::new(f64_field(obj, "vcpus")?, u32_field(obj, "mem_mb")?),
        predicted_runtime: opt_f64_field(obj, "predicted_runtime")?,
        predicted_cost: opt_f64_field(obj, "predicted_cost")?,
        state: str_field(obj, "state")?,
        runtime_secs: opt_f64_field(obj, "runtime_secs")?,
        cost: opt_f64_field(obj, "cost")?,
        output: opt_str_field(obj, "output")?,
        metrics: f64_pairs_from_json(obj, "metrics")?,
        error: opt_str_field(obj, "error")?,
    })
}

// ---------------------------------------------------------------------
// cluster: node pools, nodes, counters
// ---------------------------------------------------------------------

/// Wire shape of one node-pool configuration (`PUT /v1/cluster/pools`
/// body, and the config half of [`PoolStatus`]).
#[derive(Debug, Clone, PartialEq)]
pub struct PoolSpec {
    pub name: String,
    pub vcpus: f64,
    pub mem_mb: u32,
    /// Per-node NIC bandwidth in MB/s (cold input chunks transfer at
    /// this rate).
    pub bandwidth_mbps: f64,
    pub price_multiplier: f64,
    pub min_nodes: usize,
    pub max_nodes: usize,
    pub preemption_mean_secs: f64,
}

impl PoolSpec {
    pub fn from_config(c: &PoolConfig) -> PoolSpec {
        PoolSpec {
            name: c.name.clone(),
            vcpus: c.spec.vcpus,
            mem_mb: c.spec.mem_mb,
            bandwidth_mbps: c.spec.bandwidth_mbps,
            price_multiplier: c.price_multiplier,
            min_nodes: c.min_nodes,
            max_nodes: c.max_nodes,
            preemption_mean_secs: c.preemption_mean_secs,
        }
    }

    pub fn to_config(&self) -> PoolConfig {
        PoolConfig {
            name: self.name.clone(),
            spec: NodeSpec {
                vcpus: self.vcpus,
                mem_mb: self.mem_mb,
                bandwidth_mbps: self.bandwidth_mbps,
            },
            price_multiplier: self.price_multiplier,
            min_nodes: self.min_nodes,
            max_nodes: self.max_nodes,
            preemption_mean_secs: self.preemption_mean_secs,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .field("name", self.name.as_str())
            .field("vcpus", self.vcpus)
            .field("mem_mb", self.mem_mb)
            .field("bandwidth_mbps", self.bandwidth_mbps)
            .field("price_multiplier", self.price_multiplier)
            .field("min_nodes", self.min_nodes)
            .field("max_nodes", self.max_nodes)
            .field("preemption_mean_secs", self.preemption_mean_secs)
            .build()
    }

    /// Strict codec: `price_multiplier` defaults to 1.0 (on-demand),
    /// `preemption_mean_secs` to 0.0 (never revoked), and
    /// `bandwidth_mbps` to the platform default NIC; everything else is
    /// required.
    pub fn from_json(v: &Json) -> Result<PoolSpec> {
        let obj = as_object(v)?;
        check_fields(
            obj,
            &[
                "name",
                "vcpus",
                "mem_mb",
                "bandwidth_mbps",
                "price_multiplier",
                "min_nodes",
                "max_nodes",
                "preemption_mean_secs",
            ],
        )?;
        Ok(PoolSpec {
            name: str_field(obj, "name")?,
            vcpus: f64_field(obj, "vcpus")?,
            mem_mb: u32_field(obj, "mem_mb")?,
            bandwidth_mbps: opt_f64_field(obj, "bandwidth_mbps")?
                .unwrap_or(crate::cluster::DEFAULT_BANDWIDTH_MBPS),
            price_multiplier: opt_f64_field(obj, "price_multiplier")?.unwrap_or(1.0),
            min_nodes: u64_field(obj, "min_nodes")? as usize,
            max_nodes: u64_field(obj, "max_nodes")? as usize,
            preemption_mean_secs: opt_f64_field(obj, "preemption_mean_secs")?.unwrap_or(0.0),
        })
    }
}

/// One pool's config + live state (`GET /v1/cluster/pools`).
#[derive(Debug, Clone, PartialEq)]
pub struct PoolStatus {
    pub spec: PoolSpec,
    /// Current live node count.
    pub nodes: usize,
    /// Nodes lost to spot revocation so far.
    pub preempted_nodes: u64,
}

impl PoolStatus {
    pub fn from_snapshot(s: &PoolSnapshot) -> PoolStatus {
        PoolStatus {
            spec: PoolSpec::from_config(&s.config),
            nodes: s.nodes,
            preempted_nodes: s.preempted_nodes,
        }
    }

    pub fn to_json(&self) -> Json {
        let mut obj = self
            .spec
            .to_json()
            .as_object()
            .cloned()
            .unwrap_or_default();
        obj.set("nodes", self.nodes);
        obj.set("preempted_nodes", self.preempted_nodes);
        Json::Obj(obj)
    }

    pub fn from_json(v: &Json) -> Result<PoolStatus> {
        let obj = as_object(v)?;
        let nodes = u64_field(obj, "nodes")? as usize;
        let preempted_nodes = u64_field(obj, "preempted_nodes")?;
        // the remaining fields are the spec; re-read them strictly
        let mut spec_obj = obj.clone();
        spec_obj.remove("nodes");
        spec_obj.remove("preempted_nodes");
        Ok(PoolStatus {
            spec: PoolSpec::from_json(&Json::Obj(spec_obj))?,
            nodes,
            preempted_nodes,
        })
    }
}

/// One live node (`GET /v1/cluster/nodes`).
#[derive(Debug, Clone, PartialEq)]
pub struct NodeStatus {
    /// `node-N` id string.
    pub id: String,
    pub pool: String,
    pub vcpus: f64,
    pub mem_mb: u32,
    pub bandwidth_mbps: f64,
    pub used_milli_vcpus: u64,
    pub used_mem_mb: u32,
    pub containers: usize,
    /// Bytes resident in the node's chunk cache (data locality).
    pub cached_bytes: u64,
}

impl NodeStatus {
    pub fn from_snapshot(s: &NodeSnapshot) -> NodeStatus {
        NodeStatus {
            id: s.id.to_string(),
            pool: s.pool.clone(),
            vcpus: s.spec.vcpus,
            mem_mb: s.spec.mem_mb,
            bandwidth_mbps: s.spec.bandwidth_mbps,
            used_milli_vcpus: s.used_milli,
            used_mem_mb: s.used_mem,
            containers: s.containers,
            cached_bytes: s.cached_bytes,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .field("id", self.id.as_str())
            .field("pool", self.pool.as_str())
            .field("vcpus", self.vcpus)
            .field("mem_mb", self.mem_mb)
            .field("bandwidth_mbps", self.bandwidth_mbps)
            .field("used_milli_vcpus", self.used_milli_vcpus)
            .field("used_mem_mb", self.used_mem_mb)
            .field("containers", self.containers)
            .field("cached_bytes", self.cached_bytes)
            .build()
    }

    pub fn from_json(v: &Json) -> Result<NodeStatus> {
        let obj = as_object(v)?;
        Ok(NodeStatus {
            id: str_field(obj, "id")?,
            pool: str_field(obj, "pool")?,
            vcpus: f64_field(obj, "vcpus")?,
            mem_mb: u32_field(obj, "mem_mb")?,
            bandwidth_mbps: f64_field(obj, "bandwidth_mbps")?,
            used_milli_vcpus: u64_field(obj, "used_milli_vcpus")?,
            used_mem_mb: u32_field(obj, "used_mem_mb")?,
            containers: u64_field(obj, "containers")? as usize,
            cached_bytes: u64_field(obj, "cached_bytes")?,
        })
    }
}

/// The cluster counter block served under `/v1/metrics`.
pub fn cluster_counters_to_json(c: &ClusterCounters) -> Json {
    Json::obj()
        .field("containers_launched", c.launched)
        .field("containers_completed", c.completed)
        .field("containers_preempted", c.preempted_containers)
        .field("nodes_preempted", c.preempted_nodes)
        .field("scale_up_events", c.scale_up_events)
        .field("scale_down_events", c.scale_down_events)
        .field("nodes_added", c.nodes_added)
        .field("nodes_removed", c.nodes_removed)
        .field("placement_failures", c.placement_failures)
        .field("cache_hit_bytes", c.cache_hit_bytes)
        .field("cold_bytes_transferred", c.cold_bytes_transferred)
        .field("transfer_micros", c.transfer_micros)
        .build()
}

// ---------------------------------------------------------------------
// provenance + provisioning
// ---------------------------------------------------------------------

/// Trace direction for `GET /v1/filesets/{name}/trace`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TraceDir {
    Forward,
    Backward,
}

impl TraceDir {
    pub fn as_str(self) -> &'static str {
        match self {
            TraceDir::Forward => "forward",
            TraceDir::Backward => "backward",
        }
    }

    pub fn parse(s: &str) -> Result<TraceDir> {
        match s {
            "forward" => Ok(TraceDir::Forward),
            "backward" => Ok(TraceDir::Backward),
            other => Err(AcaiError::invalid(format!(
                "unknown trace direction {other:?} (expected forward|backward)"
            ))),
        }
    }
}

pub fn edge_to_json(e: &crate::graphstore::Edge) -> Json {
    Json::obj()
        .field("from", e.from.as_str())
        .field("to", e.to.as_str())
        .field("action", e.action.as_str())
        .field("kind", e.kind.as_str())
        .build()
}

pub fn edge_from_json(v: &Json) -> Result<crate::graphstore::Edge> {
    let obj = as_object(v)?;
    Ok(crate::graphstore::Edge {
        from: str_field(obj, "from")?,
        to: str_field(obj, "to")?,
        action: str_field(obj, "action")?,
        kind: str_field(obj, "kind")?,
    })
}

/// The auto-provisioner's answer, wire-sized (the full scored grid of
/// [`Decision`] stays server-side; Fig 16 consumers use the SDK
/// in-process).
#[derive(Debug, Clone)]
pub struct ProvisionChoice {
    pub config: ResourceConfig,
    pub predicted_runtime: f64,
    pub predicted_cost: f64,
}

impl ProvisionChoice {
    pub fn from_decision(d: &Decision) -> ProvisionChoice {
        ProvisionChoice {
            config: d.config,
            predicted_runtime: d.predicted_runtime,
            predicted_cost: d.predicted_cost,
        }
    }

    pub fn to_json(&self) -> Json {
        Json::obj()
            .field("vcpus", self.config.vcpus)
            .field("mem_mb", self.config.mem_mb)
            .field("predicted_runtime", self.predicted_runtime)
            .field("predicted_cost", self.predicted_cost)
            .build()
    }

    pub fn from_json(v: &Json) -> Result<ProvisionChoice> {
        let obj = as_object(v)?;
        Ok(ProvisionChoice {
            config: ResourceConfig::new(f64_field(obj, "vcpus")?, u32_field(obj, "mem_mb")?),
            predicted_runtime: f64_field(obj, "predicted_runtime")?,
            predicted_cost: f64_field(obj, "predicted_cost")?,
        })
    }
}

pub fn objective_to_json(o: &Objective) -> Json {
    match o {
        Objective::MinRuntime { max_cost } => Json::obj()
            .field("kind", "min_runtime")
            .field("max_cost", *max_cost)
            .build(),
        Objective::MinCost { max_runtime } => Json::obj()
            .field("kind", "min_cost")
            .field("max_runtime", *max_runtime)
            .build(),
    }
}

pub fn objective_from_json(v: &Json) -> Result<Objective> {
    let obj = as_object(v)?;
    check_fields(obj, &["kind", "max_cost", "max_runtime"])?;
    match str_field(obj, "kind")?.as_str() {
        "min_runtime" => Ok(Objective::MinRuntime {
            max_cost: f64_field(obj, "max_cost")?,
        }),
        "min_cost" => Ok(Objective::MinCost {
            max_runtime: f64_field(obj, "max_runtime")?,
        }),
        other => Err(AcaiError::invalid(format!(
            "unknown objective kind {other:?} (expected min_runtime|min_cost)"
        ))),
    }
}

// ---------------------------------------------------------------------
// tracing (job + request span timelines)
// ---------------------------------------------------------------------

/// One span event on a trace timeline (`GET /v1/trace/...`).  `span`
/// is the deterministic 64-bit span id rendered as fixed-width hex
/// (f64-backed JSON numbers cannot carry 64 bits losslessly), and
/// `seq` is the event's ordinal WITHIN its trace — the store's global
/// sequence interleaves across traces nondeterministically under
/// concurrent API threads, so it never crosses the wire.
#[derive(Debug, Clone, PartialEq)]
pub struct TraceEvent {
    pub span: String,
    pub name: String,
    /// Sim-clock timestamp (virtual seconds).
    pub at: f64,
    pub seq: u64,
    pub fields: Vec<(String, Json)>,
}

impl TraceEvent {
    pub fn from_span(e: &crate::obs::SpanEvent, ordinal: u64) -> TraceEvent {
        TraceEvent {
            span: format!("{:016x}", e.span),
            name: e.name.clone(),
            at: e.at,
            seq: ordinal,
            fields: e.fields.clone(),
        }
    }

    pub fn to_json(&self) -> Json {
        let mut fields = JsonObject::new();
        for (k, v) in &self.fields {
            fields.set(k.clone(), v.clone());
        }
        Json::obj()
            .field("span", self.span.as_str())
            .field("name", self.name.as_str())
            .field("at", self.at)
            .field("seq", self.seq)
            .field("fields", fields)
            .build()
    }

    pub fn from_json(v: &Json) -> Result<TraceEvent> {
        let obj = as_object(v)?;
        check_fields(obj, &["span", "name", "at", "seq", "fields"])?;
        let fields = match obj.get("fields") {
            Some(Json::Obj(o)) => o.iter().map(|(k, v)| (k.to_string(), v.clone())).collect(),
            Some(_) => return Err(AcaiError::invalid("field \"fields\" must be an object")),
            None => Vec::new(),
        };
        Ok(TraceEvent {
            span: str_field(obj, "span")?,
            name: str_field(obj, "name")?,
            at: f64_field(obj, "at")?,
            seq: u64_field(obj, "seq")?,
            fields,
        })
    }

    /// Convenience: look up one structured field by key.
    pub fn field(&self, key: &str) -> Option<&Json> {
        self.fields.iter().find(|(k, _)| k == key).map(|(_, v)| v)
    }
}

/// The full lifecycle timeline of one job (`GET /v1/trace/jobs/{id}`)
/// plus the per-phase durations derived from it: time queued, cold
/// input transfer, useful run time, and post-checkpoint rework paid to
/// preemptions.
#[derive(Debug, Clone, PartialEq)]
pub struct JobTrace {
    pub job: JobId,
    pub state: String,
    pub preemptions: u64,
    pub queue_wait: f64,
    pub transfer: f64,
    pub run: f64,
    pub rework: f64,
    pub events: Vec<TraceEvent>,
}

impl JobTrace {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .field("job", self.job.to_string())
            .field("state", self.state.as_str())
            .field("preemptions", self.preemptions)
            .field(
                "phases",
                Json::obj()
                    .field("queue_wait_secs", self.queue_wait)
                    .field("transfer_secs", self.transfer)
                    .field("run_secs", self.run)
                    .field("rework_secs", self.rework)
                    .build(),
            )
            .field(
                "events",
                Json::Arr(self.events.iter().map(TraceEvent::to_json).collect()),
            )
            .build()
    }

    pub fn from_json(v: &Json) -> Result<JobTrace> {
        let obj = as_object(v)?;
        check_fields(obj, &["job", "state", "preemptions", "phases", "events"])?;
        let phases = match obj.get("phases") {
            Some(Json::Obj(o)) => o,
            _ => return Err(AcaiError::invalid("field \"phases\" must be an object")),
        };
        check_fields(
            phases,
            &["queue_wait_secs", "transfer_secs", "run_secs", "rework_secs"],
        )?;
        Ok(JobTrace {
            job: str_field(obj, "job")?.parse()?,
            state: str_field(obj, "state")?,
            preemptions: u64_field(obj, "preemptions")?,
            queue_wait: f64_field(phases, "queue_wait_secs")?,
            transfer: f64_field(phases, "transfer_secs")?,
            run: f64_field(phases, "run_secs")?,
            rework: f64_field(phases, "rework_secs")?,
            events: arr_field(obj, "events")?
                .iter()
                .map(TraceEvent::from_json)
                .collect::<Result<_>>()?,
        })
    }
}

/// One API request's span events (`GET /v1/trace/requests/{rid}`),
/// keyed by the `x-request-id` its response carried.
#[derive(Debug, Clone, PartialEq)]
pub struct RequestTrace {
    pub request_id: String,
    pub events: Vec<TraceEvent>,
}

impl RequestTrace {
    pub fn to_json(&self) -> Json {
        Json::obj()
            .field("request_id", self.request_id.as_str())
            .field(
                "events",
                Json::Arr(self.events.iter().map(TraceEvent::to_json).collect()),
            )
            .build()
    }

    pub fn from_json(v: &Json) -> Result<RequestTrace> {
        let obj = as_object(v)?;
        check_fields(obj, &["request_id", "events"])?;
        Ok(RequestTrace {
            request_id: str_field(obj, "request_id")?,
            events: arr_field(obj, "events")?
                .iter()
                .map(TraceEvent::from_json)
                .collect::<Result<_>>()?,
        })
    }
}

/// Zero-padded numeric cursor so lexicographic cursor comparison
/// matches numeric order.
pub fn num_cursor(n: u64) -> String {
    format!("{n:020}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn base64_round_trips() {
        for data in [
            &b""[..],
            b"f",
            b"fo",
            b"foo",
            b"foob",
            b"fooba",
            b"foobar",
            &[0u8, 255, 17, 3, 99],
        ] {
            let enc = b64_encode(data);
            assert_eq!(b64_decode(&enc).unwrap(), data, "{enc}");
        }
        assert_eq!(b64_encode(b"foobar"), "Zm9vYmFy");
        assert_eq!(b64_encode(b"fo"), "Zm8=");
    }

    #[test]
    fn base64_rejects_garbage() {
        assert!(b64_decode("Zm9vYmF").is_err()); // bad length
        assert!(b64_decode("Zm9v!mFy").is_err()); // bad char
        assert!(b64_decode("Zm=v").is_err()); // pad in the middle of a chunk
        assert!(b64_decode("Zm8=Zm8=").is_err()); // pad before the final chunk
        assert!(b64_decode("====").is_err());
    }

    #[test]
    fn tenant_usage_report_round_trips() {
        let report = TenantUsageReport {
            project: "proj-3".into(),
            requests: 120,
            request_bytes: 4096,
            response_bytes: 65536,
            throttled: 7,
            rejected: 2,
            api_cost: 0.000054,
        };
        let back = TenantUsageReport::from_json(&report.to_json()).unwrap();
        assert_eq!(back, report);
        // strict codec: unknown fields are 400
        let v = crate::json::parse(
            r#"{"project":"p","requests":1,"request_bytes":0,"response_bytes":0,"throttled":0,"rejected":0,"api_cost":0,"extra":1}"#,
        )
        .unwrap();
        assert_eq!(TenantUsageReport::from_json(&v).unwrap_err().status(), 400);
    }

    #[test]
    fn version_tag_key_is_reserved() {
        let err =
            validate_tags(&[("version".into(), Json::from(99u64))]).unwrap_err();
        assert_eq!(err.status(), 400);
        assert!(err.to_string().contains("reserved"), "{err}");
        assert!(validate_tags(&[("model".into(), Json::from("BERT"))]).is_ok());
    }

    #[test]
    fn unknown_fields_are_rejected() {
        let v = crate::json::parse(
            r#"{"name":"j","command":"python t.py --epoch 1","output_fileset":"o","vcpus":1,"mem_mb":512,"vcpu":2}"#,
        )
        .unwrap();
        let err = job_request_from_json(&v).unwrap_err();
        assert_eq!(err.status(), 400);
        assert!(err.to_string().contains("vcpu"), "{err}");
    }

    #[test]
    fn missing_required_fields_are_rejected_not_defaulted() {
        let v = crate::json::parse(r#"{"name":"j"}"#).unwrap();
        assert_eq!(job_request_from_json(&v).unwrap_err().status(), 400);
    }

    #[test]
    fn wrong_typed_optional_fields_are_errors_not_none() {
        // a strict codec must not mask wire corruption as "absent"
        let v = crate::json::parse(
            r#"{"job":"job-1","name":"j","state":"finished","command":"c","submitted_at":0,"runtime_secs":"3.2"}"#,
        )
        .unwrap();
        assert_eq!(JobStatus::from_json(&v).unwrap_err().status(), 400);
        let v = crate::json::parse(
            r#"{"job":"job-1","name":"j","state":"finished","command":"c","submitted_at":0,"output_version":4294967296}"#,
        )
        .unwrap();
        assert_eq!(JobStatus::from_json(&v).unwrap_err().status(), 400);
    }

    #[test]
    fn out_of_range_integers_are_rejected_not_truncated() {
        // 2^32 + 512 would silently become 512 under an `as u32` cast
        let v = crate::json::parse(
            r#"{"name":"j","command":"python t.py --epoch 1","output_fileset":"o","vcpus":1,"mem_mb":4294967808}"#,
        )
        .unwrap();
        let err = job_request_from_json(&v).unwrap_err();
        assert_eq!(err.status(), 400);
        assert!(err.to_string().contains("out of range"), "{err}");
    }

    #[test]
    fn job_request_round_trips() {
        let v = crate::json::parse(
            r#"{"name":"j","command":"python t.py --epoch 1","input_fileset":"in:2","output_fileset":"o","vcpus":1.5,"mem_mb":512}"#,
        )
        .unwrap();
        let r = job_request_from_json(&v).unwrap();
        let r2 = job_request_from_json(&job_request_to_json(&r)).unwrap();
        assert_eq!(r2.name, "j");
        assert_eq!(r2.input_fileset, "in:2");
        assert_eq!(r2.resources.vcpus, 1.5);
        assert_eq!(r2.resources.mem_mb, 512);
    }

    #[test]
    fn kind_parsing_is_strict() {
        assert_eq!(kind_from_str("jobs").unwrap(), ArtifactKind::Job);
        assert_eq!(kind_from_str("files").unwrap(), ArtifactKind::File);
        assert_eq!(kind_from_str("filesets").unwrap(), ArtifactKind::FileSet);
        // the seed bug: any unknown kind silently mapped to Job
        assert_eq!(kind_from_str("job").unwrap_err().status(), 400);
        assert_eq!(kind_from_str("experiments").unwrap_err().status(), 400);
        assert_eq!(kind_from_str("").unwrap_err().status(), 400);
    }

    #[test]
    fn clauses_round_trip() {
        let clauses = [
            Clause::eq("model", "BERT"),
            Clause::gte("precision", 0.5),
            Clause::lte("cost", 2.0),
            Clause::Min("training_loss".into()),
            Clause::Max("precision".into()),
        ];
        for c in &clauses {
            let v = clause_to_json(c);
            let back = clause_from_json(&v).unwrap();
            assert_eq!(clause_to_json(&back).encode(), v.encode());
        }
        assert!(clause_from_json(&crate::json::parse(r#"{"op":"like","key":"x"}"#).unwrap()).is_err());
    }

    #[test]
    fn pagination_cuts_and_chains() {
        let items: Vec<u64> = (1..=10).collect();
        let page1 = cut_page(items.clone(), &PageReq { limit: 4, after: None }, |n| num_cursor(*n));
        assert_eq!(page1.items, vec![1, 2, 3, 4]);
        let page2 = cut_page(
            items.clone(),
            &PageReq { limit: 4, after: page1.next.clone() },
            |n| num_cursor(*n),
        );
        assert_eq!(page2.items, vec![5, 6, 7, 8]);
        let page3 = cut_page(
            items,
            &PageReq { limit: 4, after: page2.next.clone() },
            |n| num_cursor(*n),
        );
        assert_eq!(page3.items, vec![9, 10]);
        assert!(page3.next.is_none());
    }

    #[test]
    fn experiment_spec_codec_is_strict() {
        // unknown strategy is a 400, never a silent default
        let v = crate::json::parse(
            r#"{"name":"s","template":"python t.py --epoch {1,2}","strategy":"bayesian","vcpus":1,"mem_mb":512}"#,
        )
        .unwrap();
        let err = experiment_spec_from_json(&v).unwrap_err();
        assert_eq!(err.status(), 400);
        assert!(err.to_string().contains("bayesian"), "{err}");
        // grid + samples is contradictory
        let v = crate::json::parse(
            r#"{"name":"s","template":"python t.py --epoch {1,2}","strategy":"grid","samples":4,"vcpus":1,"mem_mb":512}"#,
        )
        .unwrap();
        assert_eq!(experiment_spec_from_json(&v).unwrap_err().status(), 400);
        // random needs samples
        let v = crate::json::parse(
            r#"{"name":"s","template":"python t.py --epoch {1,2}","strategy":"random","vcpus":1,"mem_mb":512}"#,
        )
        .unwrap();
        assert_eq!(experiment_spec_from_json(&v).unwrap_err().status(), 400);
    }

    #[test]
    fn experiment_spec_round_trips() {
        let v = crate::json::parse(
            r#"{"name":"s","template":"python t.py --epoch {1,2}","input_fileset":"in","strategy":"random","samples":5,"seed":9,"vcpus":1.5,"mem_mb":512,"profile":"p","objective":{"kind":"min_cost","max_runtime":60}}"#,
        )
        .unwrap();
        let spec = experiment_spec_from_json(&v).unwrap();
        let back = experiment_spec_from_json(&experiment_spec_to_json(&spec)).unwrap();
        assert_eq!(back.name, "s");
        assert_eq!(back.strategy, SweepStrategy::Random { samples: 5, seed: 9 });
        assert_eq!(back.profile.as_deref(), Some("p"));
        assert_eq!(back.objective, Some(Objective::MinCost { max_runtime: 60.0 }));
        assert_eq!(back.resources.vcpus, 1.5);
    }

    #[test]
    fn trial_status_round_trips() {
        let t = TrialStatus {
            experiment: ExperimentId(3),
            index: 7,
            job: Some(JobId(12)),
            name: "trial-0007".into(),
            command: "python t.py --epoch 2".into(),
            args: vec![("epoch".into(), 2.0)],
            resources: ResourceConfig::new(1.0, 1024),
            predicted_runtime: Some(10.5),
            predicted_cost: None,
            state: "finished".into(),
            runtime_secs: Some(9.0),
            cost: Some(0.02),
            output: Some("s-trial-0007:1".into()),
            metrics: vec![("training_loss".into(), 0.4), ("accuracy".into(), 0.9)],
            error: None,
        };
        let back = trial_status_from_json(&trial_status_to_json(&t)).unwrap();
        assert_eq!(back.index, 7);
        assert_eq!(back.job, Some(JobId(12)));
        assert_eq!(back.args, t.args);
        assert_eq!(back.metrics, t.metrics);
        assert_eq!(back.predicted_runtime, Some(10.5));
        assert_eq!(back.predicted_cost, None);
        assert_eq!(back.output.as_deref(), Some("s-trial-0007:1"));
    }

    #[test]
    fn pool_spec_codec_is_strict_and_defaults_sanely() {
        // full round trip
        let spec = PoolSpec {
            name: "spot".into(),
            vcpus: 4.0,
            mem_mb: 8192,
            bandwidth_mbps: 40.0,
            price_multiplier: 0.3,
            min_nodes: 0,
            max_nodes: 6,
            preemption_mean_secs: 12.5,
        };
        let back = PoolSpec::from_json(&spec.to_json()).unwrap();
        assert_eq!(back, spec);
        // omitted price/preemption default to on-demand semantics
        let v = crate::json::parse(
            r#"{"name":"batch","vcpus":4,"mem_mb":8192,"min_nodes":1,"max_nodes":2}"#,
        )
        .unwrap();
        let p = PoolSpec::from_json(&v).unwrap();
        assert_eq!(p.price_multiplier, 1.0);
        assert_eq!(p.preemption_mean_secs, 0.0);
        assert_eq!(p.bandwidth_mbps, crate::cluster::DEFAULT_BANDWIDTH_MBPS);
        // unknown fields are a 400 — a typo'd knob must not be ignored
        let v = crate::json::parse(
            r#"{"name":"x","vcpus":4,"mem_mb":8192,"min_nodes":1,"max_nodes":2,"preemption_rate":0.5}"#,
        )
        .unwrap();
        assert_eq!(PoolSpec::from_json(&v).unwrap_err().status(), 400);
        // missing required fields are a 400
        let v = crate::json::parse(r#"{"name":"x","vcpus":4}"#).unwrap();
        assert_eq!(PoolSpec::from_json(&v).unwrap_err().status(), 400);
    }

    #[test]
    fn pool_and_node_status_round_trip() {
        let status = PoolStatus {
            spec: PoolSpec {
                name: "spot".into(),
                vcpus: 4.0,
                mem_mb: 8192,
                bandwidth_mbps: 125.0,
                price_multiplier: 0.3,
                min_nodes: 0,
                max_nodes: 6,
                preemption_mean_secs: 9.0,
            },
            nodes: 3,
            preempted_nodes: 7,
        };
        let back = PoolStatus::from_json(&status.to_json()).unwrap();
        assert_eq!(back, status);
        let node = NodeStatus {
            id: "node-4".into(),
            pool: "spot".into(),
            vcpus: 4.0,
            mem_mb: 8192,
            bandwidth_mbps: 125.0,
            used_milli_vcpus: 1500,
            used_mem_mb: 2048,
            containers: 2,
            cached_bytes: 4096,
        };
        let back = NodeStatus::from_json(&node.to_json()).unwrap();
        assert_eq!(back, node);
    }

    #[test]
    fn job_request_pool_round_trips_and_preemptions_decode() {
        let v = crate::json::parse(
            r#"{"name":"j","command":"python t.py --epoch 1","output_fileset":"o","vcpus":1,"mem_mb":512,"pool":"spot"}"#,
        )
        .unwrap();
        let r = job_request_from_json(&v).unwrap();
        assert_eq!(r.pool.as_deref(), Some("spot"));
        let r2 = job_request_from_json(&job_request_to_json(&r)).unwrap();
        assert_eq!(r2.pool.as_deref(), Some("spot"));
        // absent pool stays unconstrained
        let v = crate::json::parse(
            r#"{"name":"j","command":"python t.py --epoch 1","output_fileset":"o","vcpus":1,"mem_mb":512}"#,
        )
        .unwrap();
        assert_eq!(job_request_from_json(&v).unwrap().pool, None);
        // a job status without the preemptions field decodes to 0; a
        // preempted one round-trips the count
        let v = crate::json::parse(
            r#"{"job":"job-1","name":"j","state":"finished","command":"c","submitted_at":0}"#,
        )
        .unwrap();
        assert_eq!(JobStatus::from_json(&v).unwrap().preemptions, 0);
        let v = crate::json::parse(
            r#"{"job":"job-1","name":"j","state":"finished","command":"c","submitted_at":0,"preemptions":3}"#,
        )
        .unwrap();
        assert_eq!(JobStatus::from_json(&v).unwrap().preemptions, 3);
    }

    #[test]
    fn priority_and_gang_round_trip_with_omitted_defaults() {
        let v = crate::json::parse(
            r#"{"name":"j","command":"python t.py --epoch 1","output_fileset":"o","vcpus":1,"mem_mb":512,"priority":"high","gang":3}"#,
        )
        .unwrap();
        let r = job_request_from_json(&v).unwrap();
        assert_eq!(r.priority, Priority::High);
        assert_eq!(r.gang, 3);
        let r2 = job_request_from_json(&job_request_to_json(&r)).unwrap();
        assert_eq!(r2.priority, Priority::High);
        assert_eq!(r2.gang, 3);
        // defaults stay off the wire and decode back to defaults
        let v = crate::json::parse(
            r#"{"name":"j","command":"python t.py --epoch 1","output_fileset":"o","vcpus":1,"mem_mb":512}"#,
        )
        .unwrap();
        let r = job_request_from_json(&v).unwrap();
        assert_eq!(r.priority, Priority::Normal);
        assert_eq!(r.gang, 1);
        let encoded = job_request_to_json(&r).encode();
        assert!(!encoded.contains("priority") && !encoded.contains("gang"), "{encoded}");
        // bad priority strings are 400
        let v = crate::json::parse(
            r#"{"name":"j","command":"python t.py --epoch 1","output_fileset":"o","vcpus":1,"mem_mb":512,"priority":"urgent"}"#,
        )
        .unwrap();
        assert_eq!(job_request_from_json(&v).unwrap_err().status(), 400);
        // job status carries both, defaulting when absent
        let v = crate::json::parse(
            r#"{"job":"job-1","name":"j","state":"running","command":"c","submitted_at":0,"priority":"low","gang":2}"#,
        )
        .unwrap();
        let s = JobStatus::from_json(&v).unwrap();
        assert_eq!(s.priority, Priority::Low);
        assert_eq!(s.gang, 2);
        let v = crate::json::parse(
            r#"{"job":"job-1","name":"j","state":"running","command":"c","submitted_at":0}"#,
        )
        .unwrap();
        let s = JobStatus::from_json(&v).unwrap();
        assert_eq!(s.priority, Priority::Normal);
        assert_eq!(s.gang, 1);
    }

    #[test]
    fn scheduler_metrics_encode_counters_and_shares() {
        let counters = SchedulerCounters {
            decisions: 10,
            launched: 7,
            requeues: 2,
            evictions: 1,
            last_pump_decisions: 3,
            max_pump_decisions: 5,
        };
        let shares = [ProjectShare {
            project: crate::ids::ProjectId(4),
            weight: 2.0,
            share: 0.25,
            queued: 6,
            active: 3,
        }];
        let v = scheduler_metrics_to_json(&counters, &shares);
        assert_eq!(v.get("launched").and_then(Json::as_u64), Some(7));
        assert_eq!(v.get("max_pump_decisions").and_then(Json::as_u64), Some(5));
        let p = v.get("projects").and_then(|p| p.at(0)).unwrap();
        assert_eq!(p.get("project").and_then(Json::as_str), Some("proj-4"));
        assert_eq!(p.get("weight").and_then(Json::as_f64), Some(2.0));
        assert_eq!(p.get("queued").and_then(Json::as_u64), Some(6));
    }

    #[test]
    fn objective_round_trips() {
        for o in [
            Objective::MinCost { max_runtime: 120.0 },
            Objective::MinRuntime { max_cost: 3.5 },
        ] {
            let back = objective_from_json(&objective_to_json(&o)).unwrap();
            assert_eq!(back, o);
        }
    }

    #[test]
    fn data_commit_pin_round_trips_in_job_and_experiment_payloads() {
        let v = crate::json::parse(
            r#"{"name":"j","command":"python t.py --epoch 1","output_fileset":"o","vcpus":1,"mem_mb":512,"data_commit":"commit-3"}"#,
        )
        .unwrap();
        let r = job_request_from_json(&v).unwrap();
        assert_eq!(r.data_commit.as_deref(), Some("commit-3"));
        let r2 = job_request_from_json(&job_request_to_json(&r)).unwrap();
        assert_eq!(r2.data_commit.as_deref(), Some("commit-3"));
        // absent pin resolves against latest
        let v = crate::json::parse(
            r#"{"name":"j","command":"python t.py --epoch 1","output_fileset":"o","vcpus":1,"mem_mb":512}"#,
        )
        .unwrap();
        assert_eq!(job_request_from_json(&v).unwrap().data_commit, None);
        let v = crate::json::parse(
            r#"{"name":"s","template":"python t.py --epoch {1,2}","strategy":"grid","vcpus":1,"mem_mb":512,"data_commit":"commit-7"}"#,
        )
        .unwrap();
        let spec = experiment_spec_from_json(&v).unwrap();
        assert_eq!(spec.data_commit.as_deref(), Some("commit-7"));
        let back = experiment_spec_from_json(&experiment_spec_to_json(&spec)).unwrap();
        assert_eq!(back.data_commit.as_deref(), Some("commit-7"));
    }

    #[test]
    fn commit_and_branch_dtos_round_trip_strictly() {
        let info = CommitInfo {
            id: "commit-4".into(),
            message: "nightly snapshot".into(),
            created_at: 12.5,
            files: 3,
            bytes: 4096,
        };
        assert_eq!(CommitInfo::from_json(&info.to_json()).unwrap(), info);
        // unknown field is a 400, not ignored
        let v = crate::json::parse(
            r#"{"commit":"commit-4","message":"m","created_at":0,"files":1,"bytes":2,"sha":"x"}"#,
        )
        .unwrap();
        assert_eq!(CommitInfo::from_json(&v).unwrap_err().status(), 400);
        let branch = BranchInfo {
            name: "main".into(),
            commit: "commit-4".into(),
            created_at: 1.0,
        };
        assert_eq!(BranchInfo::from_json(&branch.to_json()).unwrap(), branch);
        let rollback = RollbackSummary {
            branch: "main".into(),
            commit: "commit-4".into(),
            restored: 1,
            repointed: 2,
            removed: 3,
        };
        assert_eq!(
            RollbackSummary::from_json(&rollback.to_json()).unwrap(),
            rollback
        );
        let gc = GcSweepReport {
            unreferenced_files: 2,
            reclaimable_bytes: 64,
            reclaimed_chunks: 5,
            reclaimed_chunk_bytes: 320,
        };
        assert_eq!(GcSweepReport::from_json(&gc.to_json()).unwrap(), gc);
    }

    #[test]
    fn trace_dtos_round_trip_strictly() {
        let event = TraceEvent {
            span: "00ab54a98ceb1f0a".into(),
            name: "placement".into(),
            at: 1.5,
            seq: 2,
            fields: vec![("gang".into(), Json::from(2u64))],
        };
        assert_eq!(TraceEvent::from_json(&event.to_json()).unwrap(), event);
        let trace = JobTrace {
            job: JobId(3),
            state: "finished".into(),
            preemptions: 1,
            queue_wait: 2.0,
            transfer: 0.5,
            run: 10.0,
            rework: 1.5,
            events: vec![event.clone()],
        };
        let back = JobTrace::from_json(&trace.to_json()).unwrap();
        assert_eq!(back, trace);
        assert_eq!(back.events[0].field("gang").and_then(Json::as_u64), Some(2));
        let rt = RequestTrace {
            request_id: "rc1-4".into(),
            events: vec![event],
        };
        assert_eq!(RequestTrace::from_json(&rt.to_json()).unwrap(), rt);
        // unknown fields are 400, like every other strict codec
        let v = crate::json::parse(
            r#"{"span":"0","name":"n","at":0,"seq":0,"fields":{},"color":"red"}"#,
        )
        .unwrap();
        assert_eq!(TraceEvent::from_json(&v).unwrap_err().status(), 400);
    }

    #[test]
    fn trial_status_carries_a_derived_trace_key() {
        let t = TrialStatus {
            experiment: ExperimentId(1),
            index: 0,
            job: Some(JobId(9)),
            name: "trial-0000".into(),
            command: "python t.py".into(),
            args: vec![],
            resources: ResourceConfig::new(1.0, 512),
            predicted_runtime: None,
            predicted_cost: None,
            state: "running".into(),
            runtime_secs: None,
            cost: None,
            output: None,
            metrics: vec![],
            error: None,
        };
        let v = trial_status_to_json(&t);
        assert_eq!(v.get("trace").and_then(Json::as_str), Some("job-9"));
        // decode ignores the derived key; an unscheduled trial omits it
        assert_eq!(trial_status_from_json(&v).unwrap().job, Some(JobId(9)));
        let unscheduled = TrialStatus { job: None, ..t };
        assert!(trial_status_to_json(&unscheduled).get("trace").is_none());
    }

    #[test]
    fn commit_diff_round_trips_and_validates_derived_totals() {
        let diff = CommitDiff {
            added: vec![DiffEntry { path: "/d/new".into(), bytes: 7 }],
            removed: vec![DiffEntry { path: "/d/old".into(), bytes: 9 }],
            changed: vec![ChangedEntry {
                path: "/d/mut".into(),
                bytes_added: 12,
                bytes_removed: 4,
                chunks_added: 3,
                chunks_removed: 1,
            }],
        };
        let back = commit_diff_from_json(&commit_diff_to_json(&diff)).unwrap();
        assert_eq!(back, diff);
        // a wire payload whose changed_bytes disagrees with its parts
        // is corrupt, not trusted
        let v = crate::json::parse(
            r#"{"added":[],"removed":[],"changed":[{"path":"/f","bytes_added":1,"bytes_removed":1,"chunks_added":1,"chunks_removed":1,"changed_bytes":5}]}"#,
        )
        .unwrap();
        assert_eq!(commit_diff_from_json(&v).unwrap_err().status(), 400);
    }
}
