//! Multi-tenant admission control for the REST edge: per-project
//! token-bucket rate limiting, lifetime request/byte quotas, and the
//! usage counters the billing surface reads (vss's `store_id`-level
//! throttling + billing model, mapped onto ACAI projects).
//!
//! Every authenticated request passes [`TenantLayer`] after auth:
//!
//! - **rate limit** — a token bucket per project
//!   ([`TenantConfig::rate_limit_rps`] refill,
//!   [`TenantConfig::rate_limit_burst`] capacity).  An empty bucket
//!   answers `429` through the uniform envelope with a `retry-after`
//!   header carrying the exact refill wait, so well-behaved SDK
//!   clients back off precisely instead of hammering;
//! - **quotas** — lifetime admitted-request and transferred-byte caps.
//!   Exhausted quotas reject hard (`429` *without* `retry-after`:
//!   waiting will not help);
//! - **usage accounting** — requests, request/response bytes,
//!   throttle and reject counts per project, surfaced via
//!   `GET /v1/tenant`, folded into `GET /v1/metrics`, and priced by
//!   [`PricingModel::api_cost`].
//!
//! Defaults are fully permissive (rate limiting off, no quotas), so a
//! platform booted with [`crate::config::PlatformConfig::default`]
//! behaves exactly as before.

use std::collections::HashMap;
use std::sync::Mutex;
use std::time::{Duration, Instant};

use crate::error::{AcaiError, Result};
use crate::httpd::{Request, Response};
use crate::ids::ProjectId;
use crate::json::Json;
use crate::pricing::PricingModel;

use super::router::{ApiCtx, Middleware, Next};

/// How long an in-process SDK call waits out its own rate limit before
/// surfacing `Exhausted` (the remote client retries over the wire
/// instead, steered by `retry-after`).
const SELF_ADMIT_MAX_WAIT: Duration = Duration::from_secs(2);

/// Per-project admission policy.  The defaults disable everything.
#[derive(Debug, Clone)]
pub struct TenantConfig {
    /// Token-bucket refill rate, requests/second.  `0.0` disables rate
    /// limiting.
    pub rate_limit_rps: f64,
    /// Token-bucket capacity (burst allowance), in requests.
    pub rate_limit_burst: f64,
    /// Lifetime admitted-request cap per project (`None` = unlimited).
    pub request_quota: Option<u64>,
    /// Lifetime transferred-byte cap per project, request + response
    /// bodies combined (`None` = unlimited).
    pub byte_quota: Option<u64>,
}

impl Default for TenantConfig {
    fn default() -> Self {
        Self {
            rate_limit_rps: 0.0,
            rate_limit_burst: 32.0,
            request_quota: None,
            byte_quota: None,
        }
    }
}

/// Outcome of one admission decision.
#[derive(Debug)]
pub enum Admission {
    /// Serve the request (it has been counted).
    Granted,
    /// Rate-limited: retry after the given wait refills one token.
    RetryAfter(Duration),
    /// A lifetime quota is exhausted — retrying will not help.
    QuotaExceeded(&'static str),
}

/// Per-project usage counters (the billing surface).
#[derive(Debug, Clone, Default)]
pub struct TenantUsage {
    /// Requests admitted (and therefore served).
    pub requests: u64,
    /// Request-body bytes admitted.
    pub request_bytes: u64,
    /// Response-body bytes returned.
    pub response_bytes: u64,
    /// Requests bounced by the rate limiter (retryable 429s).
    pub throttled: u64,
    /// Requests rejected by an exhausted quota (hard 429s).
    pub rejected: u64,
}

struct TenantState {
    /// Token-bucket level at `refilled`.
    tokens: f64,
    refilled: Instant,
    usage: TenantUsage,
}

/// All projects' admission state, shared platform-wide.
pub struct TenantRegistry {
    config: TenantConfig,
    states: Mutex<HashMap<ProjectId, TenantState>>,
}

impl TenantRegistry {
    pub fn new(config: TenantConfig) -> TenantRegistry {
        TenantRegistry {
            config,
            states: Mutex::new(HashMap::new()),
        }
    }

    /// The policy this registry enforces.
    pub fn config(&self) -> &TenantConfig {
        &self.config
    }

    /// One admission decision for `project` carrying `request_bytes`
    /// of body.  Quotas are checked first (hard rejections), then the
    /// token bucket; a granted request is counted immediately.
    pub fn admit(&self, project: ProjectId, request_bytes: u64) -> Admission {
        let mut states = self.states.lock().unwrap();
        let burst = self.config.rate_limit_burst.max(1.0);
        let state = states.entry(project).or_insert_with(|| TenantState {
            tokens: burst,
            refilled: Instant::now(),
            usage: TenantUsage::default(),
        });
        if let Some(q) = self.config.request_quota {
            if state.usage.requests >= q {
                state.usage.rejected += 1;
                return Admission::QuotaExceeded("request quota exhausted");
            }
        }
        if let Some(q) = self.config.byte_quota {
            let transferred = state.usage.request_bytes + state.usage.response_bytes;
            if transferred + request_bytes > q {
                state.usage.rejected += 1;
                return Admission::QuotaExceeded("byte quota exhausted");
            }
        }
        let rps = self.config.rate_limit_rps;
        if rps > 0.0 {
            let now = Instant::now();
            let elapsed = now.duration_since(state.refilled).as_secs_f64();
            state.tokens = (state.tokens + elapsed * rps).min(burst);
            state.refilled = now;
            if state.tokens < 1.0 {
                state.usage.throttled += 1;
                let wait = (1.0 - state.tokens) / rps;
                return Admission::RetryAfter(Duration::from_secs_f64(wait));
            }
            state.tokens -= 1.0;
        }
        state.usage.requests += 1;
        state.usage.request_bytes += request_bytes;
        Admission::Granted
    }

    /// Admission for in-process SDK calls: waits out short rate-limit
    /// stalls (bounded by [`SELF_ADMIT_MAX_WAIT`]) and surfaces
    /// [`AcaiError::Exhausted`] on quota exhaustion or timeout.
    pub fn admit_blocking(&self, project: ProjectId, request_bytes: u64) -> Result<()> {
        let deadline = Instant::now() + SELF_ADMIT_MAX_WAIT;
        loop {
            match self.admit(project, request_bytes) {
                Admission::Granted => return Ok(()),
                Admission::QuotaExceeded(what) => {
                    return Err(AcaiError::Exhausted(format!("{what} for {project}")))
                }
                Admission::RetryAfter(wait) => {
                    if Instant::now() + wait > deadline {
                        return Err(AcaiError::Exhausted(format!(
                            "rate limit exceeded for {project}"
                        )));
                    }
                    std::thread::sleep(wait.min(Duration::from_millis(50)));
                }
            }
        }
    }

    /// Fold a served response's bytes into the project's usage.
    pub fn record_response(&self, project: ProjectId, bytes: u64) {
        let mut states = self.states.lock().unwrap();
        if let Some(state) = states.get_mut(&project) {
            state.usage.response_bytes += bytes;
        }
    }

    /// One project's usage counters (zeros if it never called).
    pub fn usage(&self, project: ProjectId) -> TenantUsage {
        self.states
            .lock()
            .unwrap()
            .get(&project)
            .map(|s| s.usage.clone())
            .unwrap_or_default()
    }

    /// Every project's usage counters, project-id-ordered — the metrics
    /// registry's tenant collector pulls this on each snapshot.
    pub fn all_usage(&self) -> Vec<(ProjectId, TenantUsage)> {
        let states = self.states.lock().unwrap();
        let mut rows: Vec<(ProjectId, TenantUsage)> = states
            .iter()
            .map(|(p, s)| (*p, s.usage.clone()))
            .collect();
        rows.sort_by_key(|(p, _)| *p);
        rows
    }

    /// The `tenants` block of `GET /v1/metrics`: per-project counters
    /// plus the priced API cost, project-ordered for determinism.
    pub fn to_json(&self, pricing: &PricingModel) -> Json {
        let states = self.states.lock().unwrap();
        let mut projects: Vec<(&ProjectId, &TenantState)> = states.iter().collect();
        projects.sort_by_key(|(p, _)| **p);
        let rows: Vec<Json> = projects
            .into_iter()
            .map(|(project, state)| {
                let u = &state.usage;
                Json::obj()
                    .field("project", project.to_string())
                    .field("requests", u.requests)
                    .field("request_bytes", u.request_bytes)
                    .field("response_bytes", u.response_bytes)
                    .field("throttled", u.throttled)
                    .field("rejected", u.rejected)
                    .field(
                        "api_cost",
                        pricing.api_cost(u.requests, u.request_bytes + u.response_bytes),
                    )
                    .build()
            })
            .collect();
        Json::obj()
            .field("rate_limit_rps", self.config.rate_limit_rps)
            .field("rate_limit_burst", self.config.rate_limit_burst)
            .field("projects", Json::Arr(rows))
            .build()
    }
}

/// Routes every token can hit even once throttled/quota-exhausted —
/// usage and traces must stay observable or a capped project cannot
/// find out why its calls bounce.
fn is_exempt(route: &str) -> bool {
    matches!(
        route,
        "GET /v1/metrics"
            | "GET /v1/tenant"
            | "GET /v1/trace/jobs/{id}"
            | "GET /v1/trace/requests/{rid}"
    )
}

/// The admission middleware.  Runs after auth (it needs the project)
/// and before the handler; a rate-limited request is answered `429`
/// **with** `retry-after` through the uniform envelope, which the
/// error path of the middleware chain cannot carry — hence the direct
/// `Ok(429)` response here.
pub struct TenantLayer;

impl Middleware for TenantLayer {
    fn call(&self, req: &Request, ctx: &mut ApiCtx, next: Next<'_>) -> Result<Response> {
        if ctx.public || is_exempt(&ctx.route) {
            return next(req, ctx);
        }
        let project = ctx.client()?.identity().project;
        let acai = ctx.acai.clone();
        match acai.tenants.admit(project, req.body.len() as u64) {
            Admission::Granted => {
                let resp = next(req, ctx)?;
                acai.tenants
                    .record_response(project, resp.body.len() as u64);
                Ok(resp)
            }
            Admission::RetryAfter(wait) => {
                let secs = wait.as_secs_f64().max(0.001);
                let e = AcaiError::Exhausted(format!(
                    "rate limit exceeded for {project}; retry after {secs:.3}s"
                ));
                let mut resp = Response::error_with_request_id(&e, Some(&ctx.request_id));
                resp.headers.push(("retry-after".into(), format!("{secs:.3}")));
                Ok(resp)
            }
            Admission::QuotaExceeded(what) => {
                Err(AcaiError::Exhausted(format!("{what} for {project}")))
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const P: ProjectId = ProjectId(1);

    #[test]
    fn permissive_defaults_admit_everything() {
        let reg = TenantRegistry::new(TenantConfig::default());
        for _ in 0..1000 {
            assert!(matches!(reg.admit(P, 10), Admission::Granted));
        }
        let u = reg.usage(P);
        assert_eq!(u.requests, 1000);
        assert_eq!(u.request_bytes, 10_000);
        assert_eq!(u.throttled, 0);
        assert_eq!(u.rejected, 0);
    }

    #[test]
    fn token_bucket_throttles_then_refills() {
        let reg = TenantRegistry::new(TenantConfig {
            rate_limit_rps: 1000.0,
            rate_limit_burst: 2.0,
            ..TenantConfig::default()
        });
        assert!(matches!(reg.admit(P, 0), Admission::Granted));
        assert!(matches!(reg.admit(P, 0), Admission::Granted));
        // bucket empty: the wait must be a positive sub-burst interval
        match reg.admit(P, 0) {
            Admission::RetryAfter(wait) => {
                assert!(wait > Duration::ZERO && wait <= Duration::from_millis(2), "{wait:?}")
            }
            other => panic!("expected RetryAfter, got {other:?}"),
        }
        assert_eq!(reg.usage(P).throttled, 1);
        // a refill interval later the bucket admits again
        std::thread::sleep(Duration::from_millis(5));
        assert!(matches!(reg.admit(P, 0), Admission::Granted));
    }

    #[test]
    fn request_quota_rejects_hard() {
        let reg = TenantRegistry::new(TenantConfig {
            request_quota: Some(2),
            ..TenantConfig::default()
        });
        assert!(matches!(reg.admit(P, 0), Admission::Granted));
        assert!(matches!(reg.admit(P, 0), Admission::Granted));
        assert!(matches!(reg.admit(P, 0), Admission::QuotaExceeded(_)));
        // quota exhaustion is terminal, unlike a throttle
        assert!(matches!(reg.admit(P, 0), Admission::QuotaExceeded(_)));
        assert_eq!(reg.usage(P).rejected, 2);
        // another project is unaffected
        assert!(matches!(reg.admit(ProjectId(2), 0), Admission::Granted));
    }

    #[test]
    fn byte_quota_counts_both_directions() {
        let reg = TenantRegistry::new(TenantConfig {
            byte_quota: Some(100),
            ..TenantConfig::default()
        });
        assert!(matches!(reg.admit(P, 40), Admission::Granted));
        reg.record_response(P, 50);
        // 40 + 50 already transferred: 20 more would cross 100
        assert!(matches!(reg.admit(P, 20), Admission::QuotaExceeded(_)));
        assert!(matches!(reg.admit(P, 5), Admission::Granted));
    }

    #[test]
    fn admit_blocking_waits_out_short_throttles() {
        let reg = TenantRegistry::new(TenantConfig {
            rate_limit_rps: 500.0,
            rate_limit_burst: 1.0,
            ..TenantConfig::default()
        });
        for _ in 0..5 {
            reg.admit_blocking(P, 0).unwrap();
        }
        assert_eq!(reg.usage(P).requests, 5);
        let reg = TenantRegistry::new(TenantConfig {
            request_quota: Some(1),
            ..TenantConfig::default()
        });
        reg.admit_blocking(P, 0).unwrap();
        let err = reg.admit_blocking(P, 0).unwrap_err();
        assert_eq!(err.status(), 429);
    }
}
