//! Path-template router + middleware chain for the `/v1` edge.
//!
//! Routes are declared as `(method, template, handler)` — e.g.
//! `("GET", "/v1/jobs/{id}", ...)` — instead of living in one flat
//! `match`.  Dispatch percent-decodes path segments and the query
//! string, binds typed path parameters, and distinguishes *unknown
//! path* (404) from *known path, wrong method* (405 + `allow` header).
//! Cross-cutting concerns (request ids, per-route metrics, token auth)
//! run as an ordered middleware chain around the matched handler.

use std::sync::Arc;

use crate::error::{AcaiError, Result};
use crate::httpd::{Request, Response};
use crate::ids::Version;
use crate::sdk::Client;

// ---------------------------------------------------------------------
// percent encoding (RFC 3986)
// ---------------------------------------------------------------------

/// Encode one path segment or query value: unreserved characters pass
/// through, everything else (including `/`) becomes `%XX`.
pub fn percent_encode(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for b in s.bytes() {
        match b {
            b'A'..=b'Z' | b'a'..=b'z' | b'0'..=b'9' | b'-' | b'_' | b'.' | b'~' => {
                out.push(b as char)
            }
            _ => out.push_str(&format!("%{b:02X}")),
        }
    }
    out
}

/// Decode `%XX` escapes; malformed escapes are a 400, never passed
/// through silently.
pub fn percent_decode(s: &str) -> Result<String> {
    let bytes = s.as_bytes();
    let mut out = Vec::with_capacity(bytes.len());
    let mut i = 0;
    while i < bytes.len() {
        if bytes[i] == b'%' {
            let hex = bytes
                .get(i + 1..i + 3)
                .and_then(|h| std::str::from_utf8(h).ok())
                .and_then(|h| u8::from_str_radix(h, 16).ok())
                .ok_or_else(|| AcaiError::invalid(format!("bad percent escape in {s:?}")))?;
            out.push(hex);
            i += 3;
        } else {
            out.push(bytes[i]);
            i += 1;
        }
    }
    String::from_utf8(out).map_err(|_| AcaiError::invalid("percent-decoded bytes are not utf-8"))
}

// ---------------------------------------------------------------------
// typed path + query parameters
// ---------------------------------------------------------------------

/// Bound `{name}` template parameters, percent-decoded.
#[derive(Debug, Default, Clone)]
pub struct PathParams(Vec<(String, String)>);

impl PathParams {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.0.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
    }

    /// Required raw parameter (template guarantees presence; missing is
    /// a programming error surfaced as 400, not a panic).
    pub fn raw(&self, name: &str) -> Result<&str> {
        self.get(name)
            .ok_or_else(|| AcaiError::invalid(format!("missing path parameter {name:?}")))
    }

    /// Typed parameter through [`std::str::FromStr`]
    /// (e.g. `params.id::<JobId>("id")`).
    pub fn id<T>(&self, name: &str) -> Result<T>
    where
        T: std::str::FromStr<Err = AcaiError>,
    {
        self.raw(name)?.parse()
    }

    /// Version-number parameter.
    pub fn version(&self, name: &str) -> Result<Version> {
        let raw = self.raw(name)?;
        raw.parse::<Version>()
            .map_err(|_| AcaiError::invalid(format!("bad version {raw:?}")))
    }
}

/// Parsed, percent-decoded query parameters.
#[derive(Debug, Default, Clone)]
pub struct Query(Vec<(String, String)>);

impl Query {
    /// Parse `a=1&b=x%2Fy`; keys without `=` get an empty value.
    pub fn parse(raw: &str) -> Result<Query> {
        let mut pairs = Vec::new();
        for pair in raw.split('&') {
            if pair.is_empty() {
                continue;
            }
            let (k, v) = pair.split_once('=').unwrap_or((pair, ""));
            pairs.push((percent_decode(k)?, percent_decode(v)?));
        }
        Ok(Query(pairs))
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.0.iter().find(|(k, _)| k == name).map(|(_, v)| v.as_str())
    }

    /// Optional non-negative integer (`?offset=`); present-but-garbage
    /// is a 400.
    pub fn u64(&self, name: &str) -> Result<Option<u64>> {
        match self.get(name) {
            None => Ok(None),
            Some(raw) => raw
                .parse::<u64>()
                .map(Some)
                .map_err(|_| AcaiError::invalid(format!("bad {name} {raw:?}"))),
        }
    }

    /// Optional version number; out-of-range values are a 400, never
    /// truncated.
    pub fn version(&self, name: &str) -> Result<Option<Version>> {
        match self.get(name) {
            None => Ok(None),
            Some(raw) => raw
                .parse::<Version>()
                .map(Some)
                .map_err(|_| AcaiError::invalid(format!("bad {name} {raw:?}"))),
        }
    }
}

// ---------------------------------------------------------------------
// routes
// ---------------------------------------------------------------------

/// Per-request context threaded through the middleware chain into the
/// handler.
pub struct ApiCtx {
    pub acai: Arc<crate::platform::Acai>,
    /// Unique id stamped on the response (`x-request-id`) and into
    /// every error envelope.
    pub request_id: String,
    /// The matched route's label (metrics key), e.g.
    /// `"GET /v1/jobs/{id}"`.
    pub route: String,
    /// Whether the matched route skips token auth.
    pub public: bool,
    pub params: PathParams,
    pub query: Query,
    /// Set by the auth middleware on non-public routes.
    client: Option<Client>,
    /// The raw bearer token (some handlers re-delegate, e.g. user
    /// creation checks admin rights against it).
    pub token: Option<String>,
}

impl ApiCtx {
    pub fn new(
        acai: Arc<crate::platform::Acai>,
        request_id: String,
        route: &Route,
        params: PathParams,
        query: Query,
    ) -> ApiCtx {
        ApiCtx {
            acai,
            request_id,
            route: format!("{} {}", route.method, route.template),
            public: route.public,
            params,
            query,
            client: None,
            token: None,
        }
    }

    pub fn set_client(&mut self, client: Client, token: String) {
        self.client = Some(client);
        self.token = Some(token);
    }

    /// The authenticated SDK client (guaranteed on non-public routes).
    pub fn client(&self) -> Result<&Client> {
        self.client
            .as_ref()
            .ok_or_else(|| AcaiError::Unauthorized("route requires authentication".into()))
    }
}

/// A route endpoint.
pub type RouteHandler = Arc<dyn Fn(&Request, &mut ApiCtx) -> Result<Response> + Send + Sync>;

enum Seg {
    Lit(&'static str),
    Param(&'static str),
}

/// One declared route.
pub struct Route {
    pub method: &'static str,
    pub template: &'static str,
    /// Public routes skip token auth (project bootstrap, health).
    pub public: bool,
    segments: Vec<Seg>,
    pub handler: RouteHandler,
}

/// Dispatch outcome.
pub enum Match<'r> {
    /// Matched: route + bound params.
    Route(&'r Route, PathParams),
    /// Path exists under a different method set.
    MethodNotAllowed(Vec<&'static str>),
    /// No template matches the path.
    NotFound,
}

/// The routing table.
#[derive(Default)]
pub struct Router {
    routes: Vec<Route>,
}

impl Router {
    pub fn new() -> Router {
        Router::default()
    }

    /// Declare an authenticated route.
    pub fn route(
        &mut self,
        method: &'static str,
        template: &'static str,
        handler: RouteHandler,
    ) -> &mut Self {
        self.push(method, template, false, handler)
    }

    /// Declare a public (unauthenticated) route.
    pub fn public(
        &mut self,
        method: &'static str,
        template: &'static str,
        handler: RouteHandler,
    ) -> &mut Self {
        self.push(method, template, true, handler)
    }

    fn push(
        &mut self,
        method: &'static str,
        template: &'static str,
        public: bool,
        handler: RouteHandler,
    ) -> &mut Self {
        let segments = template
            .split('/')
            .filter(|s| !s.is_empty())
            .map(|s| {
                if let Some(name) = s.strip_prefix('{').and_then(|s| s.strip_suffix('}')) {
                    Seg::Param(name)
                } else {
                    Seg::Lit(s)
                }
            })
            .collect();
        self.routes.push(Route {
            method,
            template,
            public,
            segments,
            handler,
        });
        self
    }

    /// Match a request path.  Percent-decodes each segment before
    /// binding parameters (so `/v1/files/%2Fdata%2Fa.bin` binds
    /// `path = "/data/a.bin"`).
    pub fn dispatch(&self, method: &str, path: &str) -> Result<Match<'_>> {
        let raw_segs: Vec<&str> = path.split('/').filter(|s| !s.is_empty()).collect();
        let mut allowed: Vec<&'static str> = Vec::new();
        let mut best: Option<(&Route, PathParams)> = None;
        for route in &self.routes {
            let Some(params) = bind(&route.segments, &raw_segs)? else {
                continue;
            };
            if route.method == method {
                if best.is_none() {
                    best = Some((route, params));
                }
            } else if !allowed.contains(&route.method) {
                allowed.push(route.method);
            }
        }
        if let Some((route, params)) = best {
            return Ok(Match::Route(route, params));
        }
        if !allowed.is_empty() {
            allowed.sort_unstable();
            return Ok(Match::MethodNotAllowed(allowed));
        }
        Ok(Match::NotFound)
    }
}

/// Try to bind a template against raw path segments.
fn bind(segments: &[Seg], raw: &[&str]) -> Result<Option<PathParams>> {
    if segments.len() != raw.len() {
        return Ok(None);
    }
    let mut params = Vec::new();
    for (seg, got) in segments.iter().zip(raw) {
        match seg {
            Seg::Lit(want) => {
                if want != got {
                    return Ok(None);
                }
            }
            Seg::Param(name) => params.push((name.to_string(), percent_decode(got)?)),
        }
    }
    Ok(Some(PathParams(params)))
}

// ---------------------------------------------------------------------
// middleware chain
// ---------------------------------------------------------------------

/// Continuation passed to middleware.
pub type Next<'a> = &'a mut dyn FnMut(&Request, &mut ApiCtx) -> Result<Response>;

/// A middleware wraps the rest of the chain (auth, request-id,
/// metrics, ...).
pub trait Middleware: Send + Sync {
    fn call(&self, req: &Request, ctx: &mut ApiCtx, next: Next<'_>) -> Result<Response>;
}

/// Run `middlewares` innermost-last around `endpoint`.
pub fn run_chain(
    middlewares: &[Arc<dyn Middleware>],
    req: &Request,
    ctx: &mut ApiCtx,
    endpoint: &RouteHandler,
) -> Result<Response> {
    match middlewares.split_first() {
        None => (**endpoint)(req, ctx),
        Some((mw, rest)) => {
            mw.call(req, ctx, &mut |rq, cx| run_chain(rest, rq, cx, endpoint))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::json::Json;

    fn ok_handler(tag: &'static str) -> RouteHandler {
        Arc::new(move |_req, ctx| {
            Ok(Response::json(
                &Json::obj()
                    .field("tag", tag)
                    .field("id", ctx.params.get("id").unwrap_or(""))
                    .build(),
            ))
        })
    }

    fn router() -> Router {
        let mut r = Router::new();
        r.route("GET", "/v1/jobs", ok_handler("list"));
        r.route("POST", "/v1/jobs", ok_handler("submit"));
        r.route("GET", "/v1/jobs/{id}", ok_handler("get"));
        r.route("GET", "/v1/files/{path}/versions/{v}", ok_handler("filev"));
        r
    }

    #[test]
    fn templates_bind_typed_params() {
        let r = router();
        match r.dispatch("GET", "/v1/jobs/job-7").unwrap() {
            Match::Route(route, params) => {
                assert_eq!(route.template, "/v1/jobs/{id}");
                assert_eq!(params.get("id"), Some("job-7"));
                let id: crate::ids::JobId = params.id("id").unwrap();
                assert_eq!(id.raw(), 7);
            }
            _ => panic!("expected a match"),
        }
    }

    #[test]
    fn percent_decoding_binds_slashes_in_segments() {
        let r = router();
        match r.dispatch("GET", "/v1/files/%2Fdata%2Fa.bin/versions/3").unwrap() {
            Match::Route(route, params) => {
                assert_eq!(route.template, "/v1/files/{path}/versions/{v}");
                assert_eq!(params.get("path"), Some("/data/a.bin"));
                assert_eq!(params.version("v").unwrap(), 3);
            }
            _ => panic!("expected a match"),
        }
        // round trip with the encoder
        assert_eq!(percent_encode("/data/a.bin"), "%2Fdata%2Fa.bin");
        assert_eq!(percent_decode("%2Fdata%2Fa.bin").unwrap(), "/data/a.bin");
    }

    #[test]
    fn method_mismatch_is_405_with_allow_set() {
        let r = router();
        match r.dispatch("DELETE", "/v1/jobs").unwrap() {
            Match::MethodNotAllowed(allow) => assert_eq!(allow, vec!["GET", "POST"]),
            _ => panic!("expected 405"),
        }
    }

    #[test]
    fn unknown_path_is_not_found() {
        let r = router();
        assert!(matches!(r.dispatch("GET", "/v1/nope").unwrap(), Match::NotFound));
        assert!(matches!(
            r.dispatch("GET", "/v1/jobs/job-1/extra").unwrap(),
            Match::NotFound
        ));
    }

    #[test]
    fn bad_percent_escape_is_invalid() {
        let r = router();
        assert!(r.dispatch("GET", "/v1/jobs/%zz").is_err());
        assert!(percent_decode("%2").is_err());
        assert!(percent_decode("%zz").is_err());
    }

    #[test]
    fn query_parses_and_decodes() {
        let q = Query::parse("limit=5&after=job%2D3&flag").unwrap();
        assert_eq!(q.get("limit"), Some("5"));
        assert_eq!(q.get("after"), Some("job-3"));
        assert_eq!(q.get("flag"), Some(""));
        assert_eq!(q.u64("limit").unwrap(), Some(5));
        assert!(q.u64("after").is_err());
        assert_eq!(q.u64("missing").unwrap(), None);
        // out of u32 range: 400, not truncation to version 1
        let q = Query::parse("version=4294967297").unwrap();
        assert!(q.version("version").is_err());
    }
}
