//! Embedded persistent table store — the MySQL analogue (paper §4.4.1).
//!
//! The paper keeps the file hierarchy, file versions, file sets and upload
//! sessions in MySQL tables.  This store provides what those paths need:
//!
//! - named tables of JSON rows keyed by a string primary key;
//! - per-key read-modify-write (the sharded successor of the paper's
//!   "server-side lock": sequential version-number assignment holds per
//!   key, without serializing unrelated keys — see [`crate::storage`]);
//! - optional append-only journal persistence with crash recovery
//!   (sessions survive a server restart, §4.4.3).
//!
//! Storage is a [`ShardedMap`] keyed by `(table, key)`: point operations
//! lock one of 16 shards, so concurrent pipelines touching different
//! keys no longer contend.  The journal is a line-oriented log of JSON
//! records ([`crate::storage::Journal`]); replaying it rebuilds the
//! tables.  `reopen()` in tests simulates a crash/restart.

use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::error::{AcaiError, Result};
use crate::json::Json;
use crate::storage::{Journal, Rmw, ShardedMap, Table, DEFAULT_SHARDS};

/// Fully-qualified row key: (table, primary key).
type RowKey = (String, String);

/// The embedded store handle.
#[derive(Clone)]
pub struct KvStore {
    map: Arc<ShardedMap<RowKey, Json>>,
    journal: Option<Arc<Journal>>,
    /// Journal flush batch (remembered so `reopen` preserves it).
    batch: usize,
    writes: Arc<AtomicU64>,
}

impl Default for KvStore {
    fn default() -> Self {
        Self::with_shards(DEFAULT_SHARDS)
    }
}

impl KvStore {
    /// Purely in-memory store with the default shard count.
    pub fn in_memory() -> Self {
        Self::default()
    }

    /// In-memory store with an explicit shard count (1 = the old global
    /// lock, for the shard-scaling bench).
    pub fn with_shards(shards: usize) -> Self {
        Self {
            map: Arc::new(ShardedMap::new(shards)),
            journal: None,
            batch: 1,
            writes: Arc::new(AtomicU64::new(0)),
        }
    }

    /// Journal-backed store; replays an existing journal on open.
    pub fn open(path: impl Into<PathBuf>) -> Result<Self> {
        Self::open_with(path, DEFAULT_SHARDS, 1)
    }

    /// Journal-backed store with explicit shard count and journal flush
    /// batch (batch 1 = write-through, the durable default).
    pub fn open_with(
        path: impl Into<PathBuf>,
        shards: usize,
        batch: usize,
    ) -> Result<Self> {
        let path = path.into();
        let map = ShardedMap::new(shards);
        for rec in Journal::replay(&path)? {
            let table = rec
                .get("t")
                .and_then(Json::as_str)
                .ok_or_else(|| AcaiError::Storage("journal: missing table".into()))?;
            let key = rec
                .get("k")
                .and_then(Json::as_str)
                .ok_or_else(|| AcaiError::Storage("journal: missing key".into()))?;
            let row_key = (table.to_string(), key.to_string());
            match rec.get("v") {
                Some(Json::Null) | None => {
                    map.remove(&row_key);
                }
                Some(v) => {
                    map.insert(row_key, v.clone());
                }
            }
        }
        Ok(Self {
            map: Arc::new(map),
            journal: Some(Arc::new(Journal::open_batched(path, batch)?)),
            batch,
            writes: Arc::new(AtomicU64::new(0)),
        })
    }

    /// Simulate a crash + restart: drop in-memory state and replay,
    /// preserving the shard count and journal batch configuration.
    pub fn reopen(&self) -> Result<Self> {
        let journal = self
            .journal
            .as_ref()
            .ok_or_else(|| AcaiError::Storage("in-memory store cannot reopen".into()))?;
        journal.flush()?;
        Self::open_with(
            journal.path().to_path_buf(),
            self.map.shard_count(),
            self.batch,
        )
    }

    fn log(&self, table: &str, key: &str, value: Option<&Json>) -> Result<()> {
        self.writes.fetch_add(1, Ordering::Relaxed);
        if let Some(journal) = &self.journal {
            let rec = Json::obj()
                .field("t", table)
                .field("k", key)
                .field("v", value.cloned().unwrap_or(Json::Null))
                .build();
            journal.append(&rec)?;
        }
        Ok(())
    }

    /// Insert or replace a row.
    pub fn put(&self, table: &str, key: &str, value: Json) -> Result<()> {
        let row_key = (table.to_string(), key.to_string());
        self.map.locked(&row_key, |shard| {
            self.log(table, key, Some(&value))?;
            shard.insert(row_key.clone(), value);
            Ok(())
        })
    }

    /// Fetch a row.
    pub fn get(&self, table: &str, key: &str) -> Option<Json> {
        self.map.get(&(table.to_string(), key.to_string()))
    }

    /// Delete a row; true if it existed.
    pub fn delete(&self, table: &str, key: &str) -> Result<bool> {
        let row_key = (table.to_string(), key.to_string());
        self.map.locked(&row_key, |shard| {
            self.log(table, key, None)?;
            Ok(shard.remove(&row_key).is_some())
        })
    }

    /// Exclusive upper bound for all keys of `table`: `table` is a strict
    /// prefix of `table\0`, so every `(table, k)` sorts below it.
    fn table_end(table: &str) -> RowKey {
        (format!("{table}\u{0}"), String::new())
    }

    /// All (key, row) pairs of a table, key-ordered.
    pub fn scan(&self, table: &str) -> Vec<(String, Json)> {
        let lo = (table.to_string(), String::new());
        self.map
            .range(lo..Self::table_end(table))
            .into_iter()
            .map(|((_, k), v)| (k, v))
            .collect()
    }

    /// (key, row) pairs with keys in [`lo`, `hi`) — range scan on the PK.
    pub fn scan_range(&self, table: &str, lo: &str, hi: &str) -> Vec<(String, Json)> {
        let lo = (table.to_string(), lo.to_string());
        let hi = (table.to_string(), hi.to_string());
        self.map
            .range(lo..hi)
            .into_iter()
            .map(|((_, k), v)| (k, v))
            .collect()
    }

    /// Keys with a given prefix (used for hierarchy listings).
    pub fn scan_prefix(&self, table: &str, prefix: &str) -> Vec<(String, Json)> {
        let lo = (table.to_string(), prefix.to_string());
        self.map
            .range(lo..Self::table_end(table))
            .into_iter()
            .map(|((_, k), v)| (k, v))
            .take_while(|(k, _)| k.starts_with(prefix))
            .collect()
    }

    /// Row count (no row clones — counts within the table's key range).
    pub fn count(&self, table: &str) -> usize {
        let lo = (table.to_string(), String::new());
        self.map.count_range(lo..Self::table_end(table))
    }

    /// Total write operations (journal appends when journaled) — perf
    /// bench counter.
    pub fn write_count(&self) -> u64 {
        self.writes.load(Ordering::Relaxed)
    }

    /// Lock shards backing the store.
    pub fn shard_count(&self) -> usize {
        self.map.shard_count()
    }
}

impl Table for KvStore {
    fn get(&self, table: &str, key: &str) -> Option<Json> {
        KvStore::get(self, table, key)
    }

    fn put(&self, table: &str, key: &str, value: Json) -> Result<()> {
        KvStore::put(self, table, key, value)
    }

    fn delete(&self, table: &str, key: &str) -> Result<bool> {
        KvStore::delete(self, table, key)
    }

    fn scan(&self, table: &str) -> Vec<(String, Json)> {
        KvStore::scan(self, table)
    }

    fn scan_prefix(&self, table: &str, prefix: &str) -> Vec<(String, Json)> {
        KvStore::scan_prefix(self, table, prefix)
    }

    fn scan_range(&self, table: &str, lo: &str, hi: &str) -> Vec<(String, Json)> {
        KvStore::scan_range(self, table, lo, hi)
    }

    fn count(&self, table: &str) -> usize {
        KvStore::count(self, table)
    }

    fn read_modify_write(
        &self,
        table: &str,
        key: &str,
        f: &mut dyn FnMut(Option<&Json>) -> Result<Rmw>,
    ) -> Result<Option<Json>> {
        let row_key = (table.to_string(), key.to_string());
        self.map.locked(&row_key, |shard| {
            let outcome = f(shard.get(&row_key))?;
            match outcome {
                Rmw::Put(v) => {
                    self.log(table, key, Some(&v))?;
                    shard.insert(row_key.clone(), v.clone());
                    Ok(Some(v))
                }
                Rmw::Delete => {
                    self.log(table, key, None)?;
                    shard.remove(&row_key);
                    Ok(None)
                }
                Rmw::Keep => Ok(shard.get(&row_key).cloned()),
            }
        })
    }

    fn flush(&self) -> Result<()> {
        if let Some(journal) = &self.journal {
            journal.flush()?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_delete() {
        let db = KvStore::in_memory();
        db.put("files", "a", Json::from(1u64)).unwrap();
        assert_eq!(db.get("files", "a").unwrap().as_u64(), Some(1));
        assert!(db.delete("files", "a").unwrap());
        assert!(db.get("files", "a").is_none());
    }

    #[test]
    fn scan_is_key_ordered() {
        let db = KvStore::in_memory();
        for k in ["c", "a", "b"] {
            db.put("t", k, Json::from(k)).unwrap();
        }
        let keys: Vec<_> = db.scan("t").into_iter().map(|(k, _)| k).collect();
        assert_eq!(keys, ["a", "b", "c"]);
    }

    #[test]
    fn tables_are_isolated_across_shards() {
        let db = KvStore::in_memory();
        db.put("t1", "k", Json::from(1u64)).unwrap();
        db.put("t2", "k", Json::from(2u64)).unwrap();
        db.put("t10", "k", Json::from(3u64)).unwrap();
        assert_eq!(db.count("t1"), 1);
        assert_eq!(db.scan("t1").len(), 1);
        assert_eq!(db.get("t2", "k").unwrap().as_u64(), Some(2));
    }

    #[test]
    fn prefix_scan_matches_hierarchy() {
        let db = KvStore::in_memory();
        for k in ["/data/a", "/data/b", "/model/x", "/data2/c"] {
            db.put("files", k, Json::Null).unwrap();
        }
        let hits = db.scan_prefix("files", "/data/");
        assert_eq!(hits.len(), 2);
    }

    #[test]
    fn rmw_is_atomic_per_key() {
        let db = KvStore::in_memory();
        db.put("vers", "/f", Json::from(0u64)).unwrap();
        let mut handles = vec![];
        for _ in 0..8 {
            let db = db.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..100 {
                    db.read_modify_write("vers", "/f", &mut |cur| {
                        let v = cur.and_then(Json::as_u64).unwrap_or(0);
                        Ok(Rmw::Put(Json::from(v + 1)))
                    })
                    .unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(db.get("vers", "/f").unwrap().as_u64(), Some(800));
    }

    #[test]
    fn journal_survives_reopen() {
        let dir = std::env::temp_dir().join(format!("acai-kv-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("journal-survives.log");
        let _ = std::fs::remove_file(&path);
        let db = KvStore::open(&path).unwrap();
        db.put("sessions", "s1", Json::obj().field("state", "pending").build())
            .unwrap();
        db.put("sessions", "s2", Json::obj().field("state", "committed").build())
            .unwrap();
        db.delete("sessions", "s1").unwrap();

        let db2 = db.reopen().unwrap();
        assert!(db2.get("sessions", "s1").is_none());
        assert_eq!(
            db2.get("sessions", "s2").unwrap().get("state").unwrap().as_str(),
            Some("committed")
        );
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn journal_rejects_corruption() {
        let dir = std::env::temp_dir().join(format!("acai-kv-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("journal-corrupt.log");
        std::fs::write(&path, "{\"t\":\"x\",\"k\":\"a\",\"v\":1}\nGARBAGE\n").unwrap();
        assert!(KvStore::open(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn batched_journal_flushes_on_reopen() {
        let dir = std::env::temp_dir().join(format!("acai-kv-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("journal-batched.log");
        let _ = std::fs::remove_file(&path);
        let db = KvStore::open_with(&path, 4, 64).unwrap();
        for i in 0..10 {
            db.put("t", &format!("k{i}"), Json::from(i as u64)).unwrap();
        }
        // reopen() flushes the batch before replaying
        let db2 = db.reopen().unwrap();
        assert_eq!(db2.count("t"), 10);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn scan_range_bounds_are_half_open() {
        let db = KvStore::in_memory();
        for k in ["a", "b", "c", "d"] {
            db.put("t", k, Json::Null).unwrap();
        }
        let keys: Vec<_> = db.scan_range("t", "b", "d").into_iter().map(|(k, _)| k).collect();
        assert_eq!(keys, ["b", "c"]);
    }

    #[test]
    fn single_shard_behaves_identically() {
        let db = KvStore::with_shards(1);
        assert_eq!(db.shard_count(), 1);
        for k in ["c", "a", "b"] {
            db.put("t", k, Json::from(k)).unwrap();
        }
        let keys: Vec<_> = db.scan("t").into_iter().map(|(k, _)| k).collect();
        assert_eq!(keys, ["a", "b", "c"]);
    }
}
