//! Embedded persistent table store — the MySQL analogue (paper §4.4.1).
//!
//! The paper keeps the file hierarchy, file versions, file sets and upload
//! sessions in MySQL tables.  This store provides what those paths need:
//!
//! - named tables of JSON rows keyed by a string primary key;
//! - read-modify-write under a per-database lock (the "server-side lock"
//!   the paper uses to guarantee sequential version-number assignment);
//! - optional append-only journal persistence with crash recovery
//!   (sessions survive a server restart, §4.4.3).
//!
//! The journal is a line-oriented log of JSON records; replaying it
//! rebuilds the tables.  `reopen()` in tests simulates a crash/restart.

use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Write};
use std::path::PathBuf;
use std::sync::{Arc, Mutex, MutexGuard};

use crate::error::{AcaiError, Result};
use crate::json::{parse, Json};

#[derive(Default)]
struct Inner {
    tables: BTreeMap<String, BTreeMap<String, Json>>,
    journal: Option<std::fs::File>,
    journal_path: Option<PathBuf>,
    writes: u64,
}

/// The embedded store handle.
#[derive(Clone, Default)]
pub struct KvStore {
    inner: Arc<Mutex<Inner>>,
}

impl KvStore {
    /// Purely in-memory store.
    pub fn in_memory() -> Self {
        Self::default()
    }

    /// Journal-backed store; replays an existing journal on open.
    pub fn open(path: impl Into<PathBuf>) -> Result<Self> {
        let path = path.into();
        let mut tables: BTreeMap<String, BTreeMap<String, Json>> = BTreeMap::new();
        if path.exists() {
            let f = std::fs::File::open(&path)?;
            for (lineno, line) in BufReader::new(f).lines().enumerate() {
                let line = line?;
                if line.trim().is_empty() {
                    continue;
                }
                let rec = parse(&line).map_err(|e| {
                    AcaiError::Storage(format!(
                        "journal {path:?} line {}: {e}",
                        lineno + 1
                    ))
                })?;
                let table = rec
                    .get("t")
                    .and_then(Json::as_str)
                    .ok_or_else(|| AcaiError::Storage("journal: missing table".into()))?;
                let key = rec
                    .get("k")
                    .and_then(Json::as_str)
                    .ok_or_else(|| AcaiError::Storage("journal: missing key".into()))?;
                match rec.get("v") {
                    Some(Json::Null) | None => {
                        tables.entry(table.into()).or_default().remove(key);
                    }
                    Some(v) => {
                        tables
                            .entry(table.into())
                            .or_default()
                            .insert(key.into(), v.clone());
                    }
                }
            }
        }
        let journal = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)?;
        Ok(Self {
            inner: Arc::new(Mutex::new(Inner {
                tables,
                journal: Some(journal),
                journal_path: Some(path),
                writes: 0,
            })),
        })
    }

    /// Simulate a crash + restart: drop in-memory state and replay.
    pub fn reopen(&self) -> Result<Self> {
        let path = self
            .inner
            .lock()
            .unwrap()
            .journal_path
            .clone()
            .ok_or_else(|| AcaiError::Storage("in-memory store cannot reopen".into()))?;
        Self::open(path)
    }

    fn log(inner: &mut Inner, table: &str, key: &str, value: Option<&Json>) -> Result<()> {
        inner.writes += 1;
        if let Some(journal) = inner.journal.as_mut() {
            let rec = Json::obj()
                .field("t", table)
                .field("k", key)
                .field("v", value.cloned().unwrap_or(Json::Null))
                .build();
            writeln!(journal, "{}", rec.encode())?;
        }
        Ok(())
    }

    /// Insert or replace a row.
    pub fn put(&self, table: &str, key: &str, value: Json) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        Self::log(&mut inner, table, key, Some(&value))?;
        inner
            .tables
            .entry(table.to_string())
            .or_default()
            .insert(key.to_string(), value);
        Ok(())
    }

    /// Fetch a row.
    pub fn get(&self, table: &str, key: &str) -> Option<Json> {
        self.inner
            .lock()
            .unwrap()
            .tables
            .get(table)
            .and_then(|t| t.get(key))
            .cloned()
    }

    /// Delete a row; true if it existed.
    pub fn delete(&self, table: &str, key: &str) -> Result<bool> {
        let mut inner = self.inner.lock().unwrap();
        Self::log(&mut inner, table, key, None)?;
        Ok(inner
            .tables
            .get_mut(table)
            .map(|t| t.remove(key).is_some())
            .unwrap_or(false))
    }

    /// All (key, row) pairs of a table, key-ordered.
    pub fn scan(&self, table: &str) -> Vec<(String, Json)> {
        self.inner
            .lock()
            .unwrap()
            .tables
            .get(table)
            .map(|t| t.iter().map(|(k, v)| (k.clone(), v.clone())).collect())
            .unwrap_or_default()
    }

    /// (key, row) pairs with keys in [`lo`, `hi`) — range scan on the PK.
    pub fn scan_range(&self, table: &str, lo: &str, hi: &str) -> Vec<(String, Json)> {
        self.inner
            .lock()
            .unwrap()
            .tables
            .get(table)
            .map(|t| {
                t.range(lo.to_string()..hi.to_string())
                    .map(|(k, v)| (k.clone(), v.clone()))
                    .collect()
            })
            .unwrap_or_default()
    }

    /// Keys with a given prefix (used for hierarchy listings).
    pub fn scan_prefix(&self, table: &str, prefix: &str) -> Vec<(String, Json)> {
        self.inner
            .lock()
            .unwrap()
            .tables
            .get(table)
            .map(|t| {
                t.range(prefix.to_string()..)
                    .take_while(|(k, _)| k.starts_with(prefix))
                    .map(|(k, v)| (k.clone(), v.clone()))
                    .collect()
            })
            .unwrap_or_default()
    }

    /// Row count.
    pub fn count(&self, table: &str) -> usize {
        self.inner
            .lock()
            .unwrap()
            .tables
            .get(table)
            .map(|t| t.len())
            .unwrap_or(0)
    }

    /// Run `f` under the database lock — the paper's "server-side lock"
    /// for sequential version assignment.  `f` gets a transaction handle
    /// with the same ops; everything it does is atomic w.r.t. other
    /// `put`/`transact` callers.
    pub fn transact<T>(&self, f: impl FnOnce(&mut Txn<'_>) -> Result<T>) -> Result<T> {
        let inner = self.inner.lock().unwrap();
        let mut txn = Txn { inner };
        f(&mut txn)
    }

    /// Total writes (journal appends) — perf bench counter.
    pub fn write_count(&self) -> u64 {
        self.inner.lock().unwrap().writes
    }
}

/// Transaction handle: same ops, already under the lock.
pub struct Txn<'a> {
    inner: MutexGuard<'a, Inner>,
}

impl Txn<'_> {
    pub fn put(&mut self, table: &str, key: &str, value: Json) -> Result<()> {
        KvStore::log(&mut self.inner, table, key, Some(&value))?;
        self.inner
            .tables
            .entry(table.to_string())
            .or_default()
            .insert(key.to_string(), value);
        Ok(())
    }

    pub fn get(&self, table: &str, key: &str) -> Option<Json> {
        self.inner
            .tables
            .get(table)
            .and_then(|t| t.get(key))
            .cloned()
    }

    pub fn delete(&mut self, table: &str, key: &str) -> Result<bool> {
        KvStore::log(&mut self.inner, table, key, None)?;
        Ok(self
            .inner
            .tables
            .get_mut(table)
            .map(|t| t.remove(key).is_some())
            .unwrap_or(false))
    }

    pub fn count(&self, table: &str) -> usize {
        self.inner.tables.get(table).map(|t| t.len()).unwrap_or(0)
    }

    pub fn scan_prefix(&self, table: &str, prefix: &str) -> Vec<(String, Json)> {
        self.inner
            .tables
            .get(table)
            .map(|t| {
                t.range(prefix.to_string()..)
                    .take_while(|(k, _)| k.starts_with(prefix))
                    .map(|(k, v)| (k.clone(), v.clone()))
                    .collect()
            })
            .unwrap_or_default()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn put_get_delete() {
        let db = KvStore::in_memory();
        db.put("files", "a", Json::from(1u64)).unwrap();
        assert_eq!(db.get("files", "a").unwrap().as_u64(), Some(1));
        assert!(db.delete("files", "a").unwrap());
        assert!(db.get("files", "a").is_none());
    }

    #[test]
    fn scan_is_key_ordered() {
        let db = KvStore::in_memory();
        for k in ["c", "a", "b"] {
            db.put("t", k, Json::from(k)).unwrap();
        }
        let keys: Vec<_> = db.scan("t").into_iter().map(|(k, _)| k).collect();
        assert_eq!(keys, ["a", "b", "c"]);
    }

    #[test]
    fn prefix_scan_matches_hierarchy() {
        let db = KvStore::in_memory();
        for k in ["/data/a", "/data/b", "/model/x", "/data2/c"] {
            db.put("files", k, Json::Null).unwrap();
        }
        let hits = db.scan_prefix("files", "/data/");
        assert_eq!(hits.len(), 2);
    }

    #[test]
    fn transact_is_atomic_read_modify_write() {
        let db = KvStore::in_memory();
        db.put("vers", "/f", Json::from(0u64)).unwrap();
        let mut handles = vec![];
        for _ in 0..8 {
            let db = db.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..100 {
                    db.transact(|txn| {
                        let v = txn.get("vers", "/f").unwrap().as_u64().unwrap();
                        txn.put("vers", "/f", Json::from(v + 1))
                    })
                    .unwrap();
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(db.get("vers", "/f").unwrap().as_u64(), Some(800));
    }

    #[test]
    fn journal_survives_reopen() {
        let dir = std::env::temp_dir().join(format!("acai-kv-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("journal-survives.log");
        let _ = std::fs::remove_file(&path);
        let db = KvStore::open(&path).unwrap();
        db.put("sessions", "s1", Json::obj().field("state", "pending").build())
            .unwrap();
        db.put("sessions", "s2", Json::obj().field("state", "committed").build())
            .unwrap();
        db.delete("sessions", "s1").unwrap();

        let db2 = db.reopen().unwrap();
        assert!(db2.get("sessions", "s1").is_none());
        assert_eq!(
            db2.get("sessions", "s2").unwrap().get("state").unwrap().as_str(),
            Some("committed")
        );
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn journal_rejects_corruption() {
        let dir = std::env::temp_dir().join(format!("acai-kv-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("journal-corrupt.log");
        std::fs::write(&path, "{\"t\":\"x\",\"k\":\"a\",\"v\":1}\nGARBAGE\n").unwrap();
        assert!(KvStore::open(&path).is_err());
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn scan_range_bounds_are_half_open() {
        let db = KvStore::in_memory();
        for k in ["a", "b", "c", "d"] {
            db.put("t", k, Json::Null).unwrap();
        }
        let keys: Vec<_> = db.scan_range("t", "b", "d").into_iter().map(|(k, _)| k).collect();
        assert_eq!(keys, ["b", "c"]);
    }
}
