//! Property DAG store — the Neo4j analogue (paper §4.5.2).
//!
//! Nodes are file sets; directed, named relationships are actions (job
//! executions or file-set creations).  Per the paper, the graph store
//! keeps only ids (metadata lives in the [`crate::docstore`]); the three
//! primary APIs are whole-graph retrieval and single-edge forward /
//! backward traversal, plus full forward/backward closure for the
//! dashboard's interactive provenance tracing.
//!
//! Adjacency lives in a [`crate::storage::ShardedMap`] keyed by node id:
//! traversals (the read-heavy dashboard paths) lock one shard per
//! visited node instead of the whole graph.  Structural mutation
//! (`add_edge`) serializes on a small writer mutex — the acyclicity
//! check must observe a stable graph — but never blocks readers of
//! unrelated nodes.
//!
//! The provenance graph must stay acyclic (file sets cannot depend on
//! their own descendants); [`GraphStore::add_edge`] rejects edges that
//! would close a cycle.

use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use crate::error::{AcaiError, Result};
use crate::json::Json;
use crate::storage::{ns_key, ns_range, ns_split, Rmw, ShardedMap, Table};

/// A directed, labeled edge (action).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Edge {
    /// Source node (input file set).
    pub from: String,
    /// Destination node (output file set).
    pub to: String,
    /// Action id ("job-<n>" or "create-<n>").
    pub action: String,
    /// Action kind ("job_execution" | "fileset_creation").
    pub kind: String,
}

/// Per-node adjacency: outgoing and incoming edges, insertion-ordered.
#[derive(Debug, Clone, Default)]
struct NodeLinks {
    out: Vec<Edge>,
    inc: Vec<Edge>,
}

/// The graph store handle.
#[derive(Clone, Default)]
pub struct GraphStore {
    nodes: Arc<ShardedMap<String, NodeLinks>>,
    /// Node property rows for the [`Table`] interface (`table␟key`).
    props: Arc<ShardedMap<String, Json>>,
    /// Serializes structural writes so the cycle check sees a stable
    /// graph; readers never take it.
    write_order: Arc<Mutex<()>>,
    edge_count: Arc<AtomicUsize>,
}

impl GraphStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add a node (idempotent).
    pub fn add_node(&self, id: &str) {
        self.nodes.locked(&id.to_string(), |shard| {
            shard.entry(id.to_string()).or_default();
        });
    }

    pub fn has_node(&self, id: &str) -> bool {
        self.nodes.contains_key(&id.to_string())
    }

    /// Add a directed edge; creates endpoints as needed.  Fails if the
    /// edge would close a cycle (provenance must stay a DAG).
    pub fn add_edge(&self, from: &str, to: &str, action: &str, kind: &str) -> Result<()> {
        if from == to {
            return Err(AcaiError::conflict(format!("self-loop on {from}")));
        }
        // Writers serialize here; the reachability walk below then
        // observes a graph no concurrent add_edge is mutating.
        let _write = self.write_order.lock().unwrap();
        if self.reaches(to, from) {
            return Err(AcaiError::conflict(format!(
                "edge {from} -> {to} would create a provenance cycle"
            )));
        }
        let edge = Edge {
            from: from.to_string(),
            to: to.to_string(),
            action: action.to_string(),
            kind: kind.to_string(),
        };
        self.nodes.locked(&from.to_string(), |shard| {
            shard.entry(from.to_string()).or_default().out.push(edge.clone());
        });
        self.nodes.locked(&to.to_string(), |shard| {
            shard.entry(to.to_string()).or_default().inc.push(edge);
        });
        self.edge_count.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Is `to` reachable from `from` following edge direction?
    fn reaches(&self, from: &str, to: &str) -> bool {
        let mut seen = HashSet::new();
        let mut queue = VecDeque::from([from.to_string()]);
        while let Some(n) = queue.pop_front() {
            if n == to {
                return true;
            }
            if !seen.insert(n.clone()) {
                continue;
            }
            if let Some(links) = self.nodes.get(&n) {
                for e in &links.out {
                    queue.push_back(e.to.clone());
                }
            }
        }
        false
    }

    /// API 1 (paper): the whole graph — (nodes, edges).
    pub fn whole_graph(&self) -> (Vec<String>, Vec<Edge>) {
        let snapshot = self.nodes.snapshot();
        let nodes: Vec<String> = snapshot.iter().map(|(id, _)| id.clone()).collect();
        let edges: Vec<Edge> = snapshot
            .into_iter()
            .flat_map(|(_, links)| links.out)
            .collect();
        (nodes, edges)
    }

    /// API 2 (paper): traverse forward by one edge from a node.
    pub fn forward(&self, id: &str) -> Vec<Edge> {
        self.nodes
            .get(&id.to_string())
            .map(|links| links.out)
            .unwrap_or_default()
    }

    /// API 3 (paper): traverse backward by one edge from a node.
    pub fn backward(&self, id: &str) -> Vec<Edge> {
        self.nodes
            .get(&id.to_string())
            .map(|links| links.inc)
            .unwrap_or_default()
    }

    /// Full downstream closure (dashboard "trace forward").
    pub fn descendants(&self, id: &str) -> Vec<String> {
        self.closure(id, true)
    }

    /// Full upstream closure (dashboard "trace backward") — the lineage
    /// needed to reproduce a file set.
    pub fn ancestors(&self, id: &str) -> Vec<String> {
        self.closure(id, false)
    }

    fn closure(&self, id: &str, forward: bool) -> Vec<String> {
        let mut seen = HashSet::new();
        let mut queue = VecDeque::from([id.to_string()]);
        while let Some(n) = queue.pop_front() {
            if let Some(links) = self.nodes.get(&n) {
                let edges = if forward { &links.out } else { &links.inc };
                for e in edges {
                    let next = if forward { &e.to } else { &e.from };
                    if seen.insert(next.clone()) {
                        queue.push_back(next.clone());
                    }
                }
            }
        }
        let mut out: Vec<_> = seen.into_iter().collect();
        out.sort();
        out
    }

    /// Topological order of all nodes (valid because the graph is a DAG).
    /// Used by workflow replay (§7.1.3 future work — implemented here).
    /// Computed over a point-in-time snapshot of the sharded adjacency.
    pub fn topo_order(&self) -> Vec<String> {
        let snapshot: HashMap<String, NodeLinks> = self.nodes.snapshot().into_iter().collect();
        let mut indeg: HashMap<&str, usize> =
            snapshot.keys().map(|n| (n.as_str(), 0)).collect();
        for links in snapshot.values() {
            for e in &links.out {
                *indeg.entry(e.to.as_str()).or_insert(0) += 1;
            }
        }
        let mut ready: Vec<&str> = indeg
            .iter()
            .filter(|(_, d)| **d == 0)
            .map(|(n, _)| *n)
            .collect();
        ready.sort();
        let mut out = Vec::with_capacity(indeg.len());
        let mut ready: VecDeque<&str> = ready.into();
        while let Some(n) = ready.pop_front() {
            out.push(n.to_string());
            if let Some(links) = snapshot.get(n) {
                let mut newly: Vec<&str> = vec![];
                for e in &links.out {
                    let t = e.to.as_str();
                    let d = indeg.get_mut(t).unwrap();
                    *d -= 1;
                    if *d == 0 {
                        newly.push(t);
                    }
                }
                newly.sort();
                ready.extend(newly);
            }
        }
        out
    }

    /// (node count, edge count).
    pub fn stats(&self) -> (usize, usize) {
        (self.nodes.len(), self.edge_count.load(Ordering::Relaxed))
    }
}

/// [`Table`] view: rows are JSON property documents attached to graph
/// nodes (`table` is the property namespace via
/// [`crate::storage::ns_key`], `key` the node id).  A put materializes
/// the node, so properties and topology stay navigable together;
/// deleting a row leaves the node and its edges intact.
impl Table for GraphStore {
    fn get(&self, table: &str, key: &str) -> Option<Json> {
        self.props.get(&ns_key(table, key))
    }

    fn put(&self, table: &str, key: &str, value: Json) -> Result<()> {
        self.add_node(key);
        self.props.insert(ns_key(table, key), value);
        Ok(())
    }

    fn delete(&self, table: &str, key: &str) -> Result<bool> {
        Ok(self.props.remove(&ns_key(table, key)).is_some())
    }

    fn scan(&self, table: &str) -> Vec<(String, Json)> {
        Table::scan_prefix(self, table, "")
    }

    fn scan_prefix(&self, table: &str, prefix: &str) -> Vec<(String, Json)> {
        let (lo, hi) = ns_range(table, prefix);
        self.props
            .range(lo..hi)
            .into_iter()
            .filter_map(|(k, v)| {
                let key = ns_split(&k)?;
                key.starts_with(prefix).then(|| (key.to_string(), v))
            })
            .collect()
    }

    fn scan_range(&self, table: &str, lo: &str, hi: &str) -> Vec<(String, Json)> {
        self.props
            .range(ns_key(table, lo)..ns_key(table, hi))
            .into_iter()
            .filter_map(|(k, v)| Some((ns_split(&k)?.to_string(), v)))
            .collect()
    }

    fn count(&self, table: &str) -> usize {
        let (lo, hi) = ns_range(table, "");
        self.props.count_range(lo..hi)
    }

    fn read_modify_write(
        &self,
        table: &str,
        key: &str,
        f: &mut dyn FnMut(Option<&Json>) -> Result<Rmw>,
    ) -> Result<Option<Json>> {
        let pkey = ns_key(table, key);
        let result = self.props.locked(&pkey, |shard| {
            let cur = shard.get(&pkey);
            match f(cur)? {
                Rmw::Put(v) => {
                    shard.insert(pkey.clone(), v.clone());
                    Ok(Some(v))
                }
                Rmw::Delete => {
                    shard.remove(&pkey);
                    Ok(None)
                }
                Rmw::Keep => Ok(shard.get(&pkey).cloned()),
            }
        })?;
        if result.is_some() {
            self.add_node(key);
        }
        Ok(result)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chain() -> GraphStore {
        // raw -> (job-1) -> features -> (job-2) -> model
        //                features -> (create-1) -> features-val
        let g = GraphStore::new();
        g.add_edge("raw", "features", "job-1", "job_execution").unwrap();
        g.add_edge("features", "model", "job-2", "job_execution").unwrap();
        g.add_edge("features", "features-val", "create-1", "fileset_creation")
            .unwrap();
        g
    }

    #[test]
    fn whole_graph_lists_everything() {
        let g = chain();
        let (nodes, edges) = g.whole_graph();
        assert_eq!(nodes, ["features", "features-val", "model", "raw"]);
        assert_eq!(edges.len(), 3);
    }

    #[test]
    fn forward_and_backward_are_single_step() {
        let g = chain();
        let fwd = g.forward("features");
        assert_eq!(fwd.len(), 2);
        let back = g.backward("features");
        assert_eq!(back.len(), 1);
        assert_eq!(back[0].action, "job-1");
    }

    #[test]
    fn closures_trace_full_lineage() {
        let g = chain();
        assert_eq!(g.descendants("raw"), ["features", "features-val", "model"]);
        assert_eq!(g.ancestors("model"), ["features", "raw"]);
        assert!(g.descendants("model").is_empty());
    }

    #[test]
    fn cycles_are_rejected() {
        let g = chain();
        let err = g.add_edge("model", "raw", "job-3", "job_execution").unwrap_err();
        assert_eq!(err.status(), 409);
        // graph unchanged
        assert_eq!(g.stats(), (4, 3));
    }

    #[test]
    fn self_loops_are_rejected() {
        let g = GraphStore::new();
        assert!(g.add_edge("a", "a", "job-1", "job_execution").is_err());
    }

    #[test]
    fn parallel_actions_between_same_nodes_are_allowed() {
        let g = GraphStore::new();
        g.add_edge("a", "b", "job-1", "job_execution").unwrap();
        g.add_edge("a", "b", "job-2", "job_execution").unwrap();
        assert_eq!(g.forward("a").len(), 2);
    }

    #[test]
    fn topo_order_respects_edges() {
        let g = chain();
        let order = g.topo_order();
        let pos = |n: &str| order.iter().position(|x| x == n).unwrap();
        assert!(pos("raw") < pos("features"));
        assert!(pos("features") < pos("model"));
        assert!(pos("features") < pos("features-val"));
        assert_eq!(order.len(), 4);
    }

    #[test]
    fn add_node_is_idempotent() {
        let g = GraphStore::new();
        g.add_node("x");
        g.add_node("x");
        assert_eq!(g.stats().0, 1);
    }

    #[test]
    fn concurrent_edge_adds_preserve_acyclicity() {
        let g = Arc::new(GraphStore::new());
        // 8 threads race to build a chain plus reverse edges; the DAG
        // invariant must hold regardless of interleaving.
        let mut handles = vec![];
        for t in 0..8u64 {
            let g = g.clone();
            handles.push(std::thread::spawn(move || {
                for i in 0..50u64 {
                    let a = format!("n{}", (t * 50 + i) % 20);
                    let b = format!("n{}", (t * 50 + i + 1) % 20);
                    let _ = g.add_edge(&a, &b, &format!("job-{t}-{i}"), "job_execution");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        // acyclic: topo order covers every node exactly once
        let (nodes, _) = g.whole_graph();
        assert_eq!(g.topo_order().len(), nodes.len());
    }

    #[test]
    fn table_rows_attach_properties_to_nodes() {
        let g = GraphStore::new();
        let table: &dyn Table = &g;
        table
            .put("meta", "fs:1", Json::obj().field("creator", "a").build())
            .unwrap();
        assert!(g.has_node("fs:1"));
        assert_eq!(
            table.get("meta", "fs:1").unwrap().get("creator").unwrap().as_str(),
            Some("a")
        );
        assert_eq!(table.scan("meta").len(), 1);
        assert!(table.delete("meta", "fs:1").unwrap());
        // the node survives its property row
        assert!(g.has_node("fs:1"));
    }
}
