//! Property DAG store — the Neo4j analogue (paper §4.5.2).
//!
//! Nodes are file sets; directed, named relationships are actions (job
//! executions or file-set creations).  Per the paper, the graph store
//! keeps only ids (metadata lives in the [`crate::docstore`]); the three
//! primary APIs are whole-graph retrieval and single-edge forward /
//! backward traversal, plus full forward/backward closure for the
//! dashboard's interactive provenance tracing.
//!
//! The provenance graph must stay acyclic (file sets cannot depend on
//! their own descendants); [`GraphStore::add_edge`] rejects edges that
//! would close a cycle.

use std::collections::{HashMap, HashSet, VecDeque};
use std::sync::{Arc, Mutex};

use crate::error::{AcaiError, Result};

/// A directed, labeled edge (action).
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Edge {
    /// Source node (input file set).
    pub from: String,
    /// Destination node (output file set).
    pub to: String,
    /// Action id ("job-<n>" or "create-<n>").
    pub action: String,
    /// Action kind ("job_execution" | "fileset_creation").
    pub kind: String,
}

#[derive(Default)]
struct Inner {
    nodes: HashSet<String>,
    edges: Vec<Edge>,
    /// Adjacency: node -> outgoing edge indexes / incoming edge indexes.
    out: HashMap<String, Vec<usize>>,
    inc: HashMap<String, Vec<usize>>,
}

/// The graph store handle.
#[derive(Clone, Default)]
pub struct GraphStore {
    inner: Arc<Mutex<Inner>>,
}

impl GraphStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// Add a node (idempotent).
    pub fn add_node(&self, id: &str) {
        self.inner.lock().unwrap().nodes.insert(id.to_string());
    }

    pub fn has_node(&self, id: &str) -> bool {
        self.inner.lock().unwrap().nodes.contains(id)
    }

    /// Add a directed edge; creates endpoints as needed.  Fails if the
    /// edge would close a cycle (provenance must stay a DAG).
    pub fn add_edge(&self, from: &str, to: &str, action: &str, kind: &str) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        if from != to && Self::reaches(&inner, to, from) {
            return Err(AcaiError::conflict(format!(
                "edge {from} -> {to} would create a provenance cycle"
            )));
        }
        if from == to {
            return Err(AcaiError::conflict(format!("self-loop on {from}")));
        }
        inner.nodes.insert(from.to_string());
        inner.nodes.insert(to.to_string());
        let idx = inner.edges.len();
        inner.edges.push(Edge {
            from: from.to_string(),
            to: to.to_string(),
            action: action.to_string(),
            kind: kind.to_string(),
        });
        inner.out.entry(from.to_string()).or_default().push(idx);
        inner.inc.entry(to.to_string()).or_default().push(idx);
        Ok(())
    }

    /// Is `to` reachable from `from` following edge direction?
    fn reaches(inner: &Inner, from: &str, to: &str) -> bool {
        let mut seen = HashSet::new();
        let mut queue = VecDeque::from([from.to_string()]);
        while let Some(n) = queue.pop_front() {
            if n == to {
                return true;
            }
            if !seen.insert(n.clone()) {
                continue;
            }
            if let Some(edges) = inner.out.get(&n) {
                for &e in edges {
                    queue.push_back(inner.edges[e].to.clone());
                }
            }
        }
        false
    }

    /// API 1 (paper): the whole graph — (nodes, edges).
    pub fn whole_graph(&self) -> (Vec<String>, Vec<Edge>) {
        let inner = self.inner.lock().unwrap();
        let mut nodes: Vec<_> = inner.nodes.iter().cloned().collect();
        nodes.sort();
        (nodes, inner.edges.clone())
    }

    /// API 2 (paper): traverse forward by one edge from a node.
    pub fn forward(&self, id: &str) -> Vec<Edge> {
        let inner = self.inner.lock().unwrap();
        inner
            .out
            .get(id)
            .map(|idxs| idxs.iter().map(|&i| inner.edges[i].clone()).collect())
            .unwrap_or_default()
    }

    /// API 3 (paper): traverse backward by one edge from a node.
    pub fn backward(&self, id: &str) -> Vec<Edge> {
        let inner = self.inner.lock().unwrap();
        inner
            .inc
            .get(id)
            .map(|idxs| idxs.iter().map(|&i| inner.edges[i].clone()).collect())
            .unwrap_or_default()
    }

    /// Full downstream closure (dashboard "trace forward").
    pub fn descendants(&self, id: &str) -> Vec<String> {
        self.closure(id, true)
    }

    /// Full upstream closure (dashboard "trace backward") — the lineage
    /// needed to reproduce a file set.
    pub fn ancestors(&self, id: &str) -> Vec<String> {
        self.closure(id, false)
    }

    fn closure(&self, id: &str, forward: bool) -> Vec<String> {
        let inner = self.inner.lock().unwrap();
        let mut seen = HashSet::new();
        let mut queue = VecDeque::from([id.to_string()]);
        while let Some(n) = queue.pop_front() {
            let adj = if forward { &inner.out } else { &inner.inc };
            if let Some(edges) = adj.get(&n) {
                for &e in edges {
                    let next = if forward {
                        &inner.edges[e].to
                    } else {
                        &inner.edges[e].from
                    };
                    if seen.insert(next.clone()) {
                        queue.push_back(next.clone());
                    }
                }
            }
        }
        let mut out: Vec<_> = seen.into_iter().collect();
        out.sort();
        out
    }

    /// Topological order of all nodes (valid because the graph is a DAG).
    /// Used by workflow replay (§7.1.3 future work — implemented here).
    pub fn topo_order(&self) -> Vec<String> {
        let inner = self.inner.lock().unwrap();
        let mut indeg: HashMap<&str, usize> =
            inner.nodes.iter().map(|n| (n.as_str(), 0)).collect();
        for e in &inner.edges {
            *indeg.entry(e.to.as_str()).or_insert(0) += 1;
        }
        let mut ready: Vec<&str> = indeg
            .iter()
            .filter(|(_, d)| **d == 0)
            .map(|(n, _)| *n)
            .collect();
        ready.sort();
        let mut out = Vec::with_capacity(indeg.len());
        let mut ready: VecDeque<&str> = ready.into();
        while let Some(n) = ready.pop_front() {
            out.push(n.to_string());
            if let Some(edges) = inner.out.get(n) {
                let mut newly: Vec<&str> = vec![];
                for &e in edges {
                    let t = inner.edges[e].to.as_str();
                    let d = indeg.get_mut(t).unwrap();
                    *d -= 1;
                    if *d == 0 {
                        newly.push(t);
                    }
                }
                newly.sort();
                ready.extend(newly);
            }
        }
        out
    }

    /// (node count, edge count).
    pub fn stats(&self) -> (usize, usize) {
        let inner = self.inner.lock().unwrap();
        (inner.nodes.len(), inner.edges.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chain() -> GraphStore {
        // raw -> (job-1) -> features -> (job-2) -> model
        //                features -> (create-1) -> features-val
        let g = GraphStore::new();
        g.add_edge("raw", "features", "job-1", "job_execution").unwrap();
        g.add_edge("features", "model", "job-2", "job_execution").unwrap();
        g.add_edge("features", "features-val", "create-1", "fileset_creation")
            .unwrap();
        g
    }

    #[test]
    fn whole_graph_lists_everything() {
        let g = chain();
        let (nodes, edges) = g.whole_graph();
        assert_eq!(nodes, ["features", "features-val", "model", "raw"]);
        assert_eq!(edges.len(), 3);
    }

    #[test]
    fn forward_and_backward_are_single_step() {
        let g = chain();
        let fwd = g.forward("features");
        assert_eq!(fwd.len(), 2);
        let back = g.backward("features");
        assert_eq!(back.len(), 1);
        assert_eq!(back[0].action, "job-1");
    }

    #[test]
    fn closures_trace_full_lineage() {
        let g = chain();
        assert_eq!(g.descendants("raw"), ["features", "features-val", "model"]);
        assert_eq!(g.ancestors("model"), ["features", "raw"]);
        assert!(g.descendants("model").is_empty());
    }

    #[test]
    fn cycles_are_rejected() {
        let g = chain();
        let err = g.add_edge("model", "raw", "job-3", "job_execution").unwrap_err();
        assert_eq!(err.status(), 409);
        // graph unchanged
        assert_eq!(g.stats(), (4, 3));
    }

    #[test]
    fn self_loops_are_rejected() {
        let g = GraphStore::new();
        assert!(g.add_edge("a", "a", "job-1", "job_execution").is_err());
    }

    #[test]
    fn parallel_actions_between_same_nodes_are_allowed() {
        let g = GraphStore::new();
        g.add_edge("a", "b", "job-1", "job_execution").unwrap();
        g.add_edge("a", "b", "job-2", "job_execution").unwrap();
        assert_eq!(g.forward("a").len(), 2);
    }

    #[test]
    fn topo_order_respects_edges() {
        let g = chain();
        let order = g.topo_order();
        let pos = |n: &str| order.iter().position(|x| x == n).unwrap();
        assert!(pos("raw") < pos("features"));
        assert!(pos("features") < pos("model"));
        assert!(pos("features") < pos("features-val"));
        assert_eq!(order.len(), 4);
    }

    #[test]
    fn add_node_is_idempotent() {
        let g = GraphStore::new();
        g.add_node("x");
        g.add_node("x");
        assert_eq!(g.stats().0, 1);
    }
}
