//! Object store + presigned URLs + upload notifications — the S3 + SNS
//! analogue (paper §4.4.2).
//!
//! The paper keeps user data off ACAI servers: clients ask the storage
//! server for **presigned URLs**, transfer bytes directly against the
//! object store, and the store notifies the storage server of completed
//! uploads through SNS.  This module reproduces that protocol:
//!
//! - [`ObjectStore::presign_put`] / [`presign_get`] mint expiring,
//!   single-use tokens scoped to one key;
//! - [`ObjectStore::put_presigned`] / [`get_presigned`] are the
//!   "direct-to-S3" data path (no ACAI service involved);
//! - completed uploads are announced on the [`crate::bus::Bus`] topic
//!   [`TOPIC_OBJECT_EVENTS`] (the SNS subscription).
//!
//! Objects and grants each live in their own
//! [`crate::storage::ShardedMap`]: concurrent uploads of different
//! objects take different shard locks, and token consumption is an
//! atomic per-grant read-modify-write — there is no store-wide lock.
//!
//! Failure injection (`fail_next_puts`) simulates dropped uploads so the
//! upload-session recovery path (§4.4.3) can be tested.

use std::sync::atomic::{AtomicU32, AtomicU64, Ordering};
use std::sync::Arc;

use crate::bus::Bus;
use crate::error::{AcaiError, Result};
use crate::json::{parse, Json};
use crate::simclock::SimClock;
use crate::storage::{ns_key, ns_range, ns_split, Bytes, Rmw, ShardedMap, Table};

/// Bus topic carrying object-store notifications (the SNS analogue).
pub const TOPIC_OBJECT_EVENTS: &str = "object-events";

/// Presigned-token lifetime, virtual seconds.
pub const PRESIGN_TTL_SECS: f64 = 3600.0;

/// Token for one presigned operation.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Presigned {
    pub token: String,
    pub key: String,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Op {
    Put,
    Get,
}

#[derive(Debug, Clone)]
struct Grant {
    key: String,
    op: Op,
    expires: f64,
    used: bool,
}

/// The simulated object store.
#[derive(Clone)]
pub struct ObjectStore {
    objects: Arc<ShardedMap<String, Bytes>>,
    grants: Arc<ShardedMap<String, Grant>>,
    clock: SimClock,
    bus: Bus,
    token_seq: Arc<AtomicU64>,
    fail_next_puts: Arc<AtomicU32>,
    bytes_stored: Arc<AtomicU64>,
}

impl ObjectStore {
    pub fn new(clock: SimClock, bus: Bus) -> Self {
        Self {
            objects: Arc::new(ShardedMap::default()),
            grants: Arc::new(ShardedMap::default()),
            clock,
            bus,
            token_seq: Arc::new(AtomicU64::new(1)),
            fail_next_puts: Arc::new(AtomicU32::new(0)),
            bytes_stored: Arc::new(AtomicU64::new(0)),
        }
    }

    fn mint(&self, key: &str, op: Op) -> Presigned {
        let n = self.token_seq.fetch_add(1, Ordering::Relaxed);
        let kind = match op {
            Op::Put => "put",
            Op::Get => "get",
        };
        let token = format!("ps-{kind}-{n:016x}");
        self.grants.insert(
            token.clone(),
            Grant {
                key: key.to_string(),
                op,
                expires: self.clock.now() + PRESIGN_TTL_SECS,
                used: false,
            },
        );
        Presigned {
            token,
            key: key.to_string(),
        }
    }

    /// Mint a presigned upload token for `key`.
    pub fn presign_put(&self, key: &str) -> Presigned {
        self.mint(key, Op::Put)
    }

    /// Mint a presigned download token for `key`.
    pub fn presign_get(&self, key: &str) -> Result<Presigned> {
        if !self.objects.contains_key(&key.to_string()) {
            return Err(AcaiError::not_found(format!("object {key}")));
        }
        Ok(self.mint(key, Op::Get))
    }

    /// Atomically validate and burn a token (single-use), under the
    /// grant's shard lock.
    fn consume(&self, token: &str, want: Op) -> Result<String> {
        let now = self.clock.now();
        self.grants.locked(&token.to_string(), |shard| {
            let grant = shard
                .get_mut(token)
                .ok_or_else(|| AcaiError::Unauthorized(format!("unknown presigned token {token}")))?;
            if grant.op != want {
                return Err(AcaiError::Unauthorized("token op mismatch".into()));
            }
            if grant.used {
                return Err(AcaiError::Unauthorized("token already used".into()));
            }
            if grant.expires < now {
                return Err(AcaiError::Unauthorized("token expired".into()));
            }
            grant.used = true;
            Ok(grant.key.clone())
        })
    }

    /// Pop one injected failure, if armed (lock-free).
    fn take_injected_failure(&self) -> bool {
        loop {
            let n = self.fail_next_puts.load(Ordering::Acquire);
            if n == 0 {
                return false;
            }
            if self
                .fail_next_puts
                .compare_exchange(n, n - 1, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                return true;
            }
        }
    }

    /// The direct-to-store upload path (client side of a presigned PUT).
    pub fn put_presigned(&self, token: &str, data: impl Into<Bytes>) -> Result<()> {
        let key = self.consume(token, Op::Put)?;
        if self.take_injected_failure() {
            return Err(AcaiError::Storage(format!(
                "injected upload failure for {key}"
            )));
        }
        self.store(&key, data);
        // SNS: notify subscribers (the storage server) of the completed put.
        self.bus.publish(
            TOPIC_OBJECT_EVENTS,
            Json::obj()
                .field("event", "put")
                .field("key", key.as_str())
                .build(),
        );
        Ok(())
    }

    /// The direct-to-store download path (presigned GET).  Returns a
    /// shared window of the stored buffer — no bytes are copied.
    pub fn get_presigned(&self, token: &str) -> Result<Bytes> {
        let key = self.consume(token, Op::Get)?;
        let data = self
            .objects
            .get(&key)
            .ok_or_else(|| AcaiError::not_found(format!("object {key}")))?;
        self.bus.publish(
            TOPIC_OBJECT_EVENTS,
            Json::obj()
                .field("event", "get")
                .field("key", key.as_str())
                .build(),
        );
        Ok(data)
    }

    /// Trusted in-platform read (agents run inside the trust boundary).
    pub fn get(&self, key: &str) -> Result<Bytes> {
        self.objects
            .get(&key.to_string())
            .ok_or_else(|| AcaiError::not_found(format!("object {key}")))
    }

    fn store(&self, key: &str, data: impl Into<Bytes>) {
        let data = data.into();
        self.bytes_stored
            .fetch_add(data.len() as u64, Ordering::Relaxed);
        self.objects.insert(key.to_string(), data);
    }

    /// Trusted in-platform write.  Accepts anything convertible to
    /// [`Bytes`]; passing an owned `Vec<u8>` or an existing `Bytes`
    /// window is zero-copy.
    pub fn put(&self, key: &str, data: impl Into<Bytes>) {
        self.store(key, data);
    }

    /// Does an object exist?
    pub fn exists(&self, key: &str) -> bool {
        self.objects.contains_key(&key.to_string())
    }

    /// Delete an object (used by session abort).
    pub fn delete(&self, key: &str) -> bool {
        self.objects.remove(&key.to_string()).is_some()
    }

    /// Inject `n` upload failures (testing the session recovery path).
    pub fn inject_put_failures(&self, n: u32) {
        self.fail_next_puts.store(n, Ordering::Release);
    }

    /// (object count, total bytes).
    pub fn stats(&self) -> (usize, u64) {
        (self.objects.len(), self.bytes_stored.load(Ordering::Relaxed))
    }
}

/// [`Table`] view: rows are JSON documents serialized into namespaced
/// objects (`table␟key`, via [`crate::storage::ns_key`]).  Gives
/// callers a uniform row interface over blob storage; binary objects
/// written through the plain [`ObjectStore::put`] path live in the
/// un-namespaced keyspace and are untouched.
impl Table for ObjectStore {
    fn get(&self, table: &str, key: &str) -> Option<Json> {
        let bytes = self.objects.get(&ns_key(table, key))?;
        parse(std::str::from_utf8(&bytes).ok()?).ok()
    }

    fn put(&self, table: &str, key: &str, value: Json) -> Result<()> {
        self.store(&ns_key(table, key), value.encode().into_bytes());
        Ok(())
    }

    fn delete(&self, table: &str, key: &str) -> Result<bool> {
        Ok(self.objects.remove(&ns_key(table, key)).is_some())
    }

    fn scan(&self, table: &str) -> Vec<(String, Json)> {
        Table::scan_prefix(self, table, "")
    }

    fn scan_prefix(&self, table: &str, prefix: &str) -> Vec<(String, Json)> {
        let (lo, hi) = ns_range(table, prefix);
        self.objects
            .range(lo..hi)
            .into_iter()
            .filter_map(|(k, v)| {
                let key = ns_split(&k)?;
                if !key.starts_with(prefix) {
                    return None;
                }
                let row = parse(std::str::from_utf8(&v).ok()?).ok()?;
                Some((key.to_string(), row))
            })
            .collect()
    }

    fn scan_range(&self, table: &str, lo: &str, hi: &str) -> Vec<(String, Json)> {
        let range = ns_key(table, lo)..ns_key(table, hi);
        self.objects
            .range(range)
            .into_iter()
            .filter_map(|(k, v)| {
                let key = ns_split(&k)?.to_string();
                let row = parse(std::str::from_utf8(&v).ok()?).ok()?;
                Some((key, row))
            })
            .collect()
    }

    fn count(&self, table: &str) -> usize {
        let (lo, hi) = ns_range(table, "");
        self.objects.count_range(lo..hi)
    }

    fn read_modify_write(
        &self,
        table: &str,
        key: &str,
        f: &mut dyn FnMut(Option<&Json>) -> Result<Rmw>,
    ) -> Result<Option<Json>> {
        let okey = ns_key(table, key);
        self.objects.locked(&okey, |shard| {
            let cur: Option<Json> = shard
                .get(&okey)
                .and_then(|b| parse(std::str::from_utf8(b).ok()?).ok());
            match f(cur.as_ref())? {
                Rmw::Put(v) => {
                    let bytes = v.encode().into_bytes();
                    self.bytes_stored
                        .fetch_add(bytes.len() as u64, Ordering::Relaxed);
                    shard.insert(okey.clone(), Bytes::from(bytes));
                    Ok(Some(v))
                }
                Rmw::Delete => {
                    shard.remove(&okey);
                    Ok(None)
                }
                Rmw::Keep => Ok(cur),
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store() -> (ObjectStore, Bus, SimClock) {
        let clock = SimClock::new();
        let bus = Bus::new();
        (ObjectStore::new(clock.clone(), bus.clone()), bus, clock)
    }

    #[test]
    fn presigned_round_trip() {
        let (s, _bus, _clock) = store();
        let up = s.presign_put("file-1");
        s.put_presigned(&up.token, b"hello".to_vec()).unwrap();
        let down = s.presign_get("file-1").unwrap();
        assert_eq!(&*s.get_presigned(&down.token).unwrap(), b"hello");
    }

    #[test]
    fn tokens_are_single_use() {
        let (s, _bus, _clock) = store();
        let up = s.presign_put("k");
        s.put_presigned(&up.token, vec![1]).unwrap();
        let err = s.put_presigned(&up.token, vec![2]).unwrap_err();
        assert_eq!(err.status(), 401);
    }

    #[test]
    fn tokens_expire_with_virtual_time() {
        let (s, _bus, clock) = store();
        let up = s.presign_put("k");
        clock.advance(PRESIGN_TTL_SECS + 1.0);
        assert_eq!(s.put_presigned(&up.token, vec![1]).unwrap_err().status(), 401);
    }

    #[test]
    fn put_token_cannot_get() {
        let (s, _bus, _clock) = store();
        s.put("k", vec![9]);
        let up = s.presign_put("k");
        assert!(s.get_presigned(&up.token).is_err());
    }

    #[test]
    fn presign_get_requires_existing_object() {
        let (s, _bus, _clock) = store();
        assert_eq!(s.presign_get("missing").unwrap_err().status(), 404);
    }

    #[test]
    fn upload_publishes_sns_notification() {
        let (s, bus, _clock) = store();
        let rx = bus.subscribe(TOPIC_OBJECT_EVENTS);
        let up = s.presign_put("file-7");
        s.put_presigned(&up.token, vec![0; 16]).unwrap();
        let event = rx.try_recv().unwrap();
        assert_eq!(event.payload.get("event").and_then(Json::as_str), Some("put"));
        assert_eq!(event.payload.get("key").and_then(Json::as_str), Some("file-7"));
    }

    #[test]
    fn injected_failures_drop_the_upload() {
        let (s, _bus, _clock) = store();
        s.inject_put_failures(1);
        let up = s.presign_put("k");
        assert!(s.put_presigned(&up.token, vec![1]).is_err());
        assert!(!s.exists("k"));
        // next upload (fresh token) succeeds
        let up2 = s.presign_put("k");
        s.put_presigned(&up2.token, vec![1]).unwrap();
        assert!(s.exists("k"));
    }

    #[test]
    fn delete_removes_object() {
        let (s, _bus, _clock) = store();
        s.put("k", vec![1, 2, 3]);
        assert!(s.delete("k"));
        assert!(!s.exists("k"));
        assert!(!s.delete("k"));
    }

    #[test]
    fn stats_track_bytes() {
        let (s, _bus, _clock) = store();
        s.put("a", vec![0; 100]);
        s.put("b", vec![0; 50]);
        let (n, bytes) = s.stats();
        assert_eq!(n, 2);
        assert_eq!(bytes, 150);
    }

    #[test]
    fn table_rows_round_trip_and_stay_namespaced() {
        let (s, _bus, _clock) = store();
        let table: &dyn Table = &s;
        table
            .put("meta", "a", Json::obj().field("x", 1u64).build())
            .unwrap();
        table
            .put("meta", "b", Json::obj().field("x", 2u64).build())
            .unwrap();
        s.put("raw-binary", vec![0xff, 0xfe]); // un-namespaced blob
        assert_eq!(
            table.get("meta", "a").unwrap().get("x").unwrap().as_u64(),
            Some(1)
        );
        let rows = table.scan("meta");
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].0, "a");
        assert!(table.delete("meta", "a").unwrap());
        assert!(table.get("meta", "a").is_none());
        // the blob is untouched by table ops
        assert!(s.exists("raw-binary"));
    }
}
