//! Object store + presigned URLs + upload notifications — the S3 + SNS
//! analogue (paper §4.4.2).
//!
//! The paper keeps user data off ACAI servers: clients ask the storage
//! server for **presigned URLs**, transfer bytes directly against the
//! object store, and the store notifies the storage server of completed
//! uploads through SNS.  This module reproduces that protocol:
//!
//! - [`ObjectStore::presign_put`] / [`presign_get`] mint expiring,
//!   single-use tokens scoped to one key;
//! - [`ObjectStore::put_presigned`] / [`get_presigned`] are the
//!   "direct-to-S3" data path (no ACAI service involved);
//! - completed uploads are announced on the [`crate::bus::Bus`] topic
//!   [`TOPIC_OBJECT_EVENTS`] (the SNS subscription).
//!
//! Failure injection (`fail_next_puts`) simulates dropped uploads so the
//! upload-session recovery path (§4.4.3) can be tested.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::bus::Bus;
use crate::error::{AcaiError, Result};
use crate::json::Json;
use crate::simclock::SimClock;

/// Bus topic carrying object-store notifications (the SNS analogue).
pub const TOPIC_OBJECT_EVENTS: &str = "object-events";

/// Presigned-token lifetime, virtual seconds.
pub const PRESIGN_TTL_SECS: f64 = 3600.0;

/// Token for one presigned operation.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct Presigned {
    pub token: String,
    pub key: String,
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Op {
    Put,
    Get,
}

#[derive(Debug)]
struct Grant {
    key: String,
    op: Op,
    expires: f64,
    used: bool,
}

#[derive(Default)]
struct Inner {
    objects: HashMap<String, Arc<Vec<u8>>>,
    grants: HashMap<String, Grant>,
    fail_next_puts: u32,
    bytes_stored: u64,
}

/// The simulated object store.
#[derive(Clone)]
pub struct ObjectStore {
    inner: Arc<Mutex<Inner>>,
    clock: SimClock,
    bus: Bus,
    token_seq: Arc<AtomicU64>,
}

impl ObjectStore {
    pub fn new(clock: SimClock, bus: Bus) -> Self {
        Self {
            inner: Arc::new(Mutex::new(Inner::default())),
            clock,
            bus,
            token_seq: Arc::new(AtomicU64::new(1)),
        }
    }

    fn mint(&self, key: &str, op: Op) -> Presigned {
        let n = self.token_seq.fetch_add(1, Ordering::Relaxed);
        let kind = match op {
            Op::Put => "put",
            Op::Get => "get",
        };
        let token = format!("ps-{kind}-{n:016x}");
        self.inner.lock().unwrap().grants.insert(
            token.clone(),
            Grant {
                key: key.to_string(),
                op,
                expires: self.clock.now() + PRESIGN_TTL_SECS,
                used: false,
            },
        );
        Presigned {
            token,
            key: key.to_string(),
        }
    }

    /// Mint a presigned upload token for `key`.
    pub fn presign_put(&self, key: &str) -> Presigned {
        self.mint(key, Op::Put)
    }

    /// Mint a presigned download token for `key`.
    pub fn presign_get(&self, key: &str) -> Result<Presigned> {
        if !self.inner.lock().unwrap().objects.contains_key(key) {
            return Err(AcaiError::not_found(format!("object {key}")));
        }
        Ok(self.mint(key, Op::Get))
    }

    fn consume(&self, token: &str, want: Op) -> Result<String> {
        let now = self.clock.now();
        let mut inner = self.inner.lock().unwrap();
        let grant = inner
            .grants
            .get_mut(token)
            .ok_or_else(|| AcaiError::Unauthorized(format!("unknown presigned token {token}")))?;
        if grant.op != want {
            return Err(AcaiError::Unauthorized("token op mismatch".into()));
        }
        if grant.used {
            return Err(AcaiError::Unauthorized("token already used".into()));
        }
        if grant.expires < now {
            return Err(AcaiError::Unauthorized("token expired".into()));
        }
        grant.used = true;
        Ok(grant.key.clone())
    }

    /// The direct-to-store upload path (client side of a presigned PUT).
    pub fn put_presigned(&self, token: &str, data: Vec<u8>) -> Result<()> {
        let key = self.consume(token, Op::Put)?;
        {
            let mut inner = self.inner.lock().unwrap();
            if inner.fail_next_puts > 0 {
                inner.fail_next_puts -= 1;
                return Err(AcaiError::Storage(format!(
                    "injected upload failure for {key}"
                )));
            }
            inner.bytes_stored += data.len() as u64;
            inner.objects.insert(key.clone(), Arc::new(data));
        }
        // SNS: notify subscribers (the storage server) of the completed put.
        self.bus.publish(
            TOPIC_OBJECT_EVENTS,
            Json::obj()
                .field("event", "put")
                .field("key", key.as_str())
                .build(),
        );
        Ok(())
    }

    /// The direct-to-store download path (presigned GET).
    pub fn get_presigned(&self, token: &str) -> Result<Arc<Vec<u8>>> {
        let key = self.consume(token, Op::Get)?;
        let data = self
            .inner
            .lock()
            .unwrap()
            .objects
            .get(&key)
            .cloned()
            .ok_or_else(|| AcaiError::not_found(format!("object {key}")))?;
        self.bus.publish(
            TOPIC_OBJECT_EVENTS,
            Json::obj()
                .field("event", "get")
                .field("key", key.as_str())
                .build(),
        );
        Ok(data)
    }

    /// Trusted in-platform read (agents run inside the trust boundary).
    pub fn get(&self, key: &str) -> Result<Arc<Vec<u8>>> {
        self.inner
            .lock()
            .unwrap()
            .objects
            .get(key)
            .cloned()
            .ok_or_else(|| AcaiError::not_found(format!("object {key}")))
    }

    /// Trusted in-platform write.
    pub fn put(&self, key: &str, data: Vec<u8>) {
        let mut inner = self.inner.lock().unwrap();
        inner.bytes_stored += data.len() as u64;
        inner.objects.insert(key.to_string(), Arc::new(data));
    }

    /// Does an object exist?
    pub fn exists(&self, key: &str) -> bool {
        self.inner.lock().unwrap().objects.contains_key(key)
    }

    /// Delete an object (used by session abort).
    pub fn delete(&self, key: &str) -> bool {
        self.inner.lock().unwrap().objects.remove(key).is_some()
    }

    /// Inject `n` upload failures (testing the session recovery path).
    pub fn inject_put_failures(&self, n: u32) {
        self.inner.lock().unwrap().fail_next_puts = n;
    }

    /// (object count, total bytes).
    pub fn stats(&self) -> (usize, u64) {
        let inner = self.inner.lock().unwrap();
        (inner.objects.len(), inner.bytes_stored)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store() -> (ObjectStore, Bus, SimClock) {
        let clock = SimClock::new();
        let bus = Bus::new();
        (ObjectStore::new(clock.clone(), bus.clone()), bus, clock)
    }

    #[test]
    fn presigned_round_trip() {
        let (s, _bus, _clock) = store();
        let up = s.presign_put("file-1");
        s.put_presigned(&up.token, b"hello".to_vec()).unwrap();
        let down = s.presign_get("file-1").unwrap();
        assert_eq!(&*s.get_presigned(&down.token).unwrap(), b"hello");
    }

    #[test]
    fn tokens_are_single_use() {
        let (s, _bus, _clock) = store();
        let up = s.presign_put("k");
        s.put_presigned(&up.token, vec![1]).unwrap();
        let err = s.put_presigned(&up.token, vec![2]).unwrap_err();
        assert_eq!(err.status(), 401);
    }

    #[test]
    fn tokens_expire_with_virtual_time() {
        let (s, _bus, clock) = store();
        let up = s.presign_put("k");
        clock.advance(PRESIGN_TTL_SECS + 1.0);
        assert_eq!(s.put_presigned(&up.token, vec![1]).unwrap_err().status(), 401);
    }

    #[test]
    fn put_token_cannot_get() {
        let (s, _bus, _clock) = store();
        s.put("k", vec![9]);
        let up = s.presign_put("k");
        assert!(s.get_presigned(&up.token).is_err());
    }

    #[test]
    fn presign_get_requires_existing_object() {
        let (s, _bus, _clock) = store();
        assert_eq!(s.presign_get("missing").unwrap_err().status(), 404);
    }

    #[test]
    fn upload_publishes_sns_notification() {
        let (s, bus, _clock) = store();
        let rx = bus.subscribe(TOPIC_OBJECT_EVENTS);
        let up = s.presign_put("file-7");
        s.put_presigned(&up.token, vec![0; 16]).unwrap();
        let event = rx.try_recv().unwrap();
        assert_eq!(event.payload.get("event").and_then(Json::as_str), Some("put"));
        assert_eq!(event.payload.get("key").and_then(Json::as_str), Some("file-7"));
    }

    #[test]
    fn injected_failures_drop_the_upload() {
        let (s, _bus, _clock) = store();
        s.inject_put_failures(1);
        let up = s.presign_put("k");
        assert!(s.put_presigned(&up.token, vec![1]).is_err());
        assert!(!s.exists("k"));
        // next upload (fresh token) succeeds
        let up2 = s.presign_put("k");
        s.put_presigned(&up2.token, vec![1]).unwrap();
        assert!(s.exists("k"));
    }

    #[test]
    fn delete_removes_object() {
        let (s, _bus, _clock) = store();
        s.put("k", vec![1, 2, 3]);
        assert!(s.delete("k"));
        assert!(!s.exists("k"));
        assert!(!s.delete("k"));
    }

    #[test]
    fn stats_track_bytes() {
        let (s, _bus, _clock) = store();
        s.put("a", vec![0; 100]);
        s.put("b", vec![0; 50]);
        let (n, bytes) = s.stats();
        assert_eq!(n, 2);
        assert_eq!(bytes, 150);
    }
}
