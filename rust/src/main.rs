//! ACAI command-line entry point.
//!
//! ```text
//! acai serve   [--port 8080] [--artifacts DIR]   REST edge (/v1, credential server)
//! acai demo    [--artifacts DIR]                 end-to-end pipeline demo
//! acai grid                                      print the provisioning grid + prices
//! acai version
//! ```
//!
//! The serve mode exposes the versioned `/v1` REST API of paper §4.1
//! over real HTTP: every request authenticates `x-acai-token`, is
//! routed by path template to the matching service, and job submission
//! is asynchronous (`POST /v1/jobs` returns 202; a background engine
//! driver completes the work).  See DESIGN.md ("The API tier") for the
//! route table.

use std::collections::HashMap;
use std::sync::Arc;

use acai::autoprovision::Objective;
use acai::cluster::ResourceConfig;
use acai::api::make_handler;
use acai::httpd::Server;
use acai::sdk::{Client, JobRequest};
use acai::{Acai, PlatformConfig};

fn parse_flags(args: &[String]) -> HashMap<String, String> {
    let mut flags = HashMap::new();
    let mut it = args.iter().peekable();
    while let Some(a) = it.next() {
        if let Some(name) = a.strip_prefix("--") {
            let value = it
                .peek()
                .filter(|v| !v.starts_with("--"))
                .map(|v| v.to_string());
            if let Some(v) = value {
                it.next();
                flags.insert(name.to_string(), v);
            } else {
                flags.insert(name.to_string(), "true".to_string());
            }
        }
    }
    flags
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let command = args.first().map(|s| s.as_str()).unwrap_or("help");
    let flags = parse_flags(&args[args.len().min(1)..]);
    let result = match command {
        "serve" => serve(&flags),
        "demo" => demo(&flags),
        "grid" => grid(),
        "version" => {
            println!("acai {}", env!("CARGO_PKG_VERSION"));
            Ok(())
        }
        _ => {
            eprintln!(
                "usage: acai <serve|demo|grid|version> [--port N] [--artifacts DIR]"
            );
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("acai: {e}");
        std::process::exit(1);
    }
}

fn boot(flags: &HashMap<String, String>) -> acai::Result<Arc<Acai>> {
    let mut config = PlatformConfig::default();
    if let Some(dir) = flags.get("artifacts") {
        config.artifacts_dir = Some(dir.into());
    }
    Ok(Arc::new(Acai::boot(config)?))
}

/// Print the provisioning grid with unit prices (paper Fig 11 / §4.3).
fn grid() -> acai::Result<()> {
    let pricing = acai::pricing::PricingModel::default();
    println!("vCPUs  unit $/vCPU-hr   512MB-job $/hr   8GB-job $/hr");
    for ci in 1..=16 {
        let c = ci as f64 * 0.5;
        let low = pricing.rate(ResourceConfig::new(c, 512)) * 3600.0;
        let high = pricing.rate(ResourceConfig::new(c, 8192)) * 3600.0;
        println!(
            "{c:>5.1}  {:>13.4}  {low:>15.4}  {high:>12.4}",
            pricing.unit_cpu(c) * 3600.0
        );
    }
    Ok(())
}

/// Minimal end-to-end pipeline on one process (see examples/ for more).
fn demo(flags: &HashMap<String, String>) -> acai::Result<()> {
    let acai = boot(flags)?;
    let root = acai.credentials.root_token().to_string();
    let (_pid, token) = acai.credentials.create_project(&root, "demo", "alice")?;
    let client = Client::connect(acai.clone(), &token)?;

    client.upload_files(&[("/data/train.bin", b"demo-data")])?;
    client.create_file_set("mnist", &["/data/train.bin"])?;
    let job = client.submit(JobRequest {
        name: "demo-train".into(),
        command: "python train_mnist.py --epoch 5".into(),
        input_fileset: "mnist".into(),
        output_fileset: "model".into(),
        resources: ResourceConfig::new(2.0, 2048),
        pool: None,
        data_commit: None,
        priority: acai::engine::Priority::Normal,
        gang: 1,
    })?;
    client.wait_all();
    let record = client.job(job)?;
    println!(
        "job {job}: state={} runtime={:.1}s cost=${:.5}",
        record.state.as_str(),
        record.runtime_secs.unwrap_or(0.0),
        record.cost.unwrap_or(0.0)
    );
    for line in client.logs(job) {
        println!("  log: {line}");
    }
    let template = client.profile(
        "demo",
        "python train_mnist.py --epoch {1,2,3}",
        "mnist",
    )?;
    let decision = client.autoprovision(
        "demo",
        &[5.0],
        Objective::MinCost { max_runtime: 120.0 },
    )?;
    println!(
        "template {template}: auto-provisioned {:.1} vCPU / {} MB, predicted {:.1}s ${:.5}",
        decision.config.vcpus,
        decision.config.mem_mb,
        decision.predicted_runtime,
        decision.predicted_cost
    );
    Ok(())
}

/// REST edge: the credential server authenticates and routes (Fig 7).
fn serve(flags: &HashMap<String, String>) -> acai::Result<()> {
    let port: u16 = flags
        .get("port")
        .map(|p| p.parse().unwrap_or(8080))
        .unwrap_or(8080);
    let acai = boot(flags)?;
    println!("root token: {}", acai.credentials.root_token());
    // start the background engine driver up front: POST /v1/jobs only
    // notifies it, nothing ever drives the engine in-request
    acai.driver();
    let http = acai.config.http.clone();
    let handler = make_handler(acai);
    let server = Server::serve_with(port, handler, http)?;
    println!(
        "acai /v1 REST edge on http://{} ({} workers, {} connection cap)",
        server.addr(),
        server.workers(),
        server.max_connections()
    );
    loop {
        std::thread::sleep(std::time::Duration::from_secs(3600));
    }
}

