//! PJRT runtime bridge: load and execute the AOT-lowered JAX/Pallas
//! modules from `artifacts/` (see `python/compile/aot.py`).
//!
//! HLO **text** is the interchange format (jax >= 0.5 emits protos with
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids).  Each module is compiled once at load; the
//! executables are reused for every call — Python never runs again.
//!
//! Exposes typed wrappers for the four entry points:
//! - [`Runtime::loglinear_fit`] / [`Runtime::loglinear_predict`] — the
//!   profiler's runtime model (paper §4.2.3);
//! - [`MlpSession`] — the MNIST MLP workload (paper §5.1), holding its
//!   parameters as tensors between steps.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::error::{AcaiError, Result};
use crate::json::{parse, Json};

pub mod pjrt;
use pjrt as xla;

/// Feature-vector width of the log-linear model (must match
/// `python/compile/model.py::FEATURES`).
pub const FEATURES: usize = 8;

/// Shape of one tensor in the manifest.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

struct Module {
    exe: xla::PjRtLoadedExecutable,
    inputs: Vec<TensorSpec>,
    outputs: Vec<TensorSpec>,
}

/// Manifest constants (shape contract with the python side).
#[derive(Debug, Clone, Copy)]
pub struct RuntimeConstants {
    pub fit_rows: usize,
    pub grid_rows: usize,
    pub mlp_in: usize,
    pub mlp_hidden: usize,
    pub mlp_out: usize,
    pub train_batch: usize,
    pub eval_batch: usize,
}

/// The loaded runtime.  Executions are serialized behind a mutex (the
/// PJRT CPU client is driven from the engine's single event loop).
pub struct Runtime {
    modules: Mutex<HashMap<String, Module>>,
    pub constants: RuntimeConstants,
    exec_count: std::sync::atomic::AtomicU64,
}

// SAFETY: the `xla` crate's handles are raw pointers + an `Rc`'d client,
// so they are not auto-Send/Sync.  All access to them in this type —
// execution, and eventually drop — goes through the `modules` Mutex,
// which serializes every cross-thread use; the client Rc is cloned only
// during `load` (single-threaded) and never after.  The PJRT CPU client
// itself is thread-safe for executing compiled executables.
unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}

fn manifest_usize(c: &Json, key: &str) -> Result<usize> {
    c.get(key)
        .and_then(Json::as_u64)
        .map(|v| v as usize)
        .ok_or_else(|| AcaiError::Runtime(format!("manifest missing constant {key}")))
}

impl Runtime {
    /// Load every module listed in `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Runtime> {
        let dir = dir.as_ref();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path).map_err(|e| {
            AcaiError::Runtime(format!(
                "cannot read {manifest_path:?}: {e}; run `make artifacts` first"
            ))
        })?;
        let manifest = parse(&text)?;
        let consts = manifest
            .get("constants")
            .ok_or_else(|| AcaiError::Runtime("manifest missing constants".into()))?;
        let features = manifest_usize(consts, "FEATURES")?;
        if features != FEATURES {
            return Err(AcaiError::Runtime(format!(
                "manifest FEATURES={features} != runtime FEATURES={FEATURES}; rebuild artifacts"
            )));
        }
        let constants = RuntimeConstants {
            fit_rows: manifest_usize(consts, "FIT_ROWS")?,
            grid_rows: manifest_usize(consts, "GRID_ROWS")?,
            mlp_in: manifest_usize(consts, "MLP_IN")?,
            mlp_hidden: manifest_usize(consts, "MLP_HIDDEN")?,
            mlp_out: manifest_usize(consts, "MLP_OUT")?,
            train_batch: manifest_usize(consts, "TRAIN_BATCH")?,
            eval_batch: manifest_usize(consts, "EVAL_BATCH")?,
        };

        let client = xla::PjRtClient::cpu()
            .map_err(|e| AcaiError::Runtime(format!("PJRT client: {e}")))?;
        let mut modules = HashMap::new();
        let mods = manifest
            .get("modules")
            .and_then(Json::as_object)
            .ok_or_else(|| AcaiError::Runtime("manifest missing modules".into()))?;
        for (name, spec) in mods.iter() {
            let file = spec
                .get("file")
                .and_then(Json::as_str)
                .ok_or_else(|| AcaiError::Runtime(format!("module {name}: no file")))?;
            let path: PathBuf = dir.join(file);
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str()
                    .ok_or_else(|| AcaiError::Runtime("non-utf8 path".into()))?,
            )
            .map_err(|e| AcaiError::Runtime(format!("parse {path:?}: {e}")))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client
                .compile(&comp)
                .map_err(|e| AcaiError::Runtime(format!("compile {name}: {e}")))?;
            let tensor_specs = |key: &str| -> Vec<TensorSpec> {
                spec.get(key)
                    .and_then(Json::as_array)
                    .unwrap_or(&[])
                    .iter()
                    .map(|t| TensorSpec {
                        name: t.get("name").and_then(Json::as_str).unwrap_or("").into(),
                        shape: t
                            .get("shape")
                            .and_then(Json::as_array)
                            .unwrap_or(&[])
                            .iter()
                            .filter_map(Json::as_u64)
                            .map(|d| d as usize)
                            .collect(),
                    })
                    .collect()
            };
            modules.insert(
                name.to_string(),
                Module {
                    exe,
                    inputs: tensor_specs("inputs"),
                    outputs: tensor_specs("outputs"),
                },
            );
        }
        Ok(Runtime {
            modules: Mutex::new(modules),
            constants,
            exec_count: std::sync::atomic::AtomicU64::new(0),
        })
    }

    /// Raw execution: f32 tensors in, f32 tensors out.
    pub fn execute(&self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        let modules = self.modules.lock().unwrap();
        let module = modules
            .get(name)
            .ok_or_else(|| AcaiError::Runtime(format!("unknown module {name}")))?;
        if inputs.len() != module.inputs.len() {
            return Err(AcaiError::Runtime(format!(
                "{name}: expected {} inputs, got {}",
                module.inputs.len(),
                inputs.len()
            )));
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (t, spec) in inputs.iter().zip(&module.inputs) {
            if t.shape != spec.shape {
                return Err(AcaiError::Runtime(format!(
                    "{name}: input {} shape {:?} != expected {:?}",
                    spec.name, t.shape, spec.shape
                )));
            }
            literals.push(t.to_literal()?);
        }
        let result = module
            .exe
            .execute::<xla::Literal>(&literals)
            .map_err(|e| AcaiError::Runtime(format!("execute {name}: {e}")))?;
        let out = result[0][0]
            .to_literal_sync()
            .map_err(|e| AcaiError::Runtime(format!("fetch {name}: {e}")))?;
        let parts = out
            .to_tuple()
            .map_err(|e| AcaiError::Runtime(format!("untuple {name}: {e}")))?;
        self.exec_count
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        parts
            .into_iter()
            .zip(&module.outputs)
            .map(|(lit, spec)| Tensor::from_literal(&lit, &spec.shape))
            .collect()
    }

    /// Number of PJRT executions so far (perf counter).
    pub fn executions(&self) -> u64 {
        self.exec_count.load(std::sync::atomic::Ordering::Relaxed)
    }

    // ------------------------------------------------------------------
    // Typed wrappers
    // ------------------------------------------------------------------

    /// Fit the log-linear runtime model.  `rows` are feature vectors
    /// (intercept first), `targets` are log-runtimes; rows beyond
    /// `rows.len()` are zero-weight padding inside the kernel.
    pub fn loglinear_fit(
        &self,
        rows: &[[f64; FEATURES]],
        targets: &[f64],
    ) -> Result<[f64; FEATURES]> {
        let n = self.constants.fit_rows;
        if rows.len() != targets.len() {
            return Err(AcaiError::invalid("rows/targets length mismatch"));
        }
        if rows.len() > n {
            return Err(AcaiError::invalid(format!(
                "{} trials > FIT_ROWS={n}; shrink the sweep or re-lower",
                rows.len()
            )));
        }
        let mut x = vec![0f32; n * FEATURES];
        let mut w = vec![0f32; n];
        let mut y = vec![0f32; n];
        for (i, (row, t)) in rows.iter().zip(targets).enumerate() {
            for (j, v) in row.iter().enumerate() {
                x[i * FEATURES + j] = *v as f32;
            }
            w[i] = 1.0;
            y[i] = *t as f32;
        }
        let out = self.execute(
            "loglinear_fit",
            &[
                Tensor::new(x, vec![n, FEATURES]),
                Tensor::new(w, vec![n, 1]),
                Tensor::new(y, vec![n, 1]),
            ],
        )?;
        let mut result = [0f64; FEATURES];
        for (i, v) in out[0].data.iter().enumerate().take(FEATURES) {
            result[i] = *v as f64;
        }
        Ok(result)
    }

    /// Predict runtimes (seconds) for a batch of feature rows.
    pub fn loglinear_predict(
        &self,
        theta: &[f64; FEATURES],
        rows: &[[f64; FEATURES]],
    ) -> Result<Vec<f64>> {
        let g = self.constants.grid_rows;
        if rows.len() > g {
            return Err(AcaiError::invalid(format!(
                "{} grid points > GRID_ROWS={g}",
                rows.len()
            )));
        }
        let mut th = vec![0f32; FEATURES];
        for (i, v) in theta.iter().enumerate() {
            th[i] = *v as f32;
        }
        let mut xg = vec![0f32; g * FEATURES];
        for (i, row) in rows.iter().enumerate() {
            for (j, v) in row.iter().enumerate() {
                xg[i * FEATURES + j] = *v as f32;
            }
        }
        let out = self.execute(
            "loglinear_predict",
            &[
                Tensor::new(th, vec![FEATURES, 1]),
                Tensor::new(xg, vec![g, FEATURES]),
            ],
        )?;
        Ok(out[0]
            .data
            .iter()
            .take(rows.len())
            .map(|v| *v as f64)
            .collect())
    }
}

/// A host-side f32 tensor.
#[derive(Debug, Clone)]
pub struct Tensor {
    pub data: Vec<f32>,
    pub shape: Vec<usize>,
}

impl Tensor {
    pub fn new(data: Vec<f32>, shape: Vec<usize>) -> Self {
        debug_assert_eq!(data.len(), shape.iter().product::<usize>());
        Self { data, shape }
    }

    pub fn scalar(v: f32) -> Self {
        Self {
            data: vec![v],
            shape: vec![],
        }
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        if self.shape.is_empty() {
            return Ok(xla::Literal::scalar(self.data[0]));
        }
        let lit = xla::Literal::vec1(&self.data);
        let dims: Vec<i64> = self.shape.iter().map(|d| *d as i64).collect();
        lit.reshape(&dims)
            .map_err(|e| AcaiError::Runtime(format!("reshape: {e}")))
    }

    fn from_literal(lit: &xla::Literal, shape: &[usize]) -> Result<Tensor> {
        let data = lit
            .to_vec::<f32>()
            .map_err(|e| AcaiError::Runtime(format!("to_vec: {e}")))?;
        Ok(Tensor::new(data, shape.to_vec()))
    }
}

/// An in-flight MLP training session: parameters persist as tensors
/// between steps; one `mlp_train_step` PJRT execution per step.
pub struct MlpSession<'r> {
    runtime: &'r Runtime,
    pub w1: Tensor,
    pub b1: Tensor,
    pub w2: Tensor,
    pub b2: Tensor,
    pub losses: Vec<f32>,
}

impl<'r> MlpSession<'r> {
    /// Initialize parameters from a seed.
    pub fn new(runtime: &'r Runtime, seed: u64) -> Self {
        let c = runtime.constants;
        let mut rng = crate::prng::Rng::new(seed);
        let mut init = |rows: usize, cols: usize, scale: f32| -> Tensor {
            let data = (0..rows * cols)
                .map(|_| rng.normal() as f32 * scale)
                .collect();
            Tensor::new(data, vec![rows, cols])
        };
        let w1 = init(c.mlp_in, c.mlp_hidden, 0.05);
        let w2 = init(c.mlp_hidden, c.mlp_out, 0.05);
        Self {
            runtime,
            w1,
            b1: Tensor::new(vec![0.0; c.mlp_hidden], vec![c.mlp_hidden]),
            w2,
            b2: Tensor::new(vec![0.0; c.mlp_out], vec![c.mlp_out]),
            losses: vec![],
        }
    }

    /// One SGD step on a (x, one-hot y) batch.  Returns the loss.
    pub fn train_step(&mut self, x: Tensor, y1h: Tensor, lr: f32) -> Result<f32> {
        let out = self.runtime.execute(
            "mlp_train_step",
            &[
                self.w1.clone(),
                self.b1.clone(),
                self.w2.clone(),
                self.b2.clone(),
                x,
                y1h,
                Tensor::scalar(lr),
            ],
        )?;
        let mut it = out.into_iter();
        self.w1 = it.next().unwrap();
        self.b1 = it.next().unwrap();
        self.w2 = it.next().unwrap();
        self.b2 = it.next().unwrap();
        let loss = it.next().unwrap().data[0];
        self.losses.push(loss);
        Ok(loss)
    }

    /// (loss, accuracy) on an eval batch.
    pub fn eval(&self, x: Tensor, y1h: Tensor) -> Result<(f32, f32)> {
        let out = self.runtime.execute(
            "mlp_eval",
            &[
                self.w1.clone(),
                self.b1.clone(),
                self.w2.clone(),
                self.b2.clone(),
                x,
                y1h,
            ],
        )?;
        Ok((out[0].data[0], out[1].data[0]))
    }

    /// Serialize the trained parameters (the job's output model file).
    pub fn serialize(&self) -> Vec<u8> {
        let mut out = Vec::new();
        for t in [&self.w1, &self.b1, &self.w2, &self.b2] {
            out.extend((t.data.len() as u32).to_le_bytes());
            for v in &t.data {
                out.extend(v.to_le_bytes());
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    //! Pure host-side tests; PJRT-backed tests live in
    //! `rust/tests/runtime_integration.rs` (they need `make artifacts`).
    use super::*;

    #[test]
    fn tensor_shape_product_checked() {
        let t = Tensor::new(vec![1.0, 2.0, 3.0, 4.0], vec![2, 2]);
        assert_eq!(t.shape, vec![2, 2]);
    }

    #[test]
    fn scalar_tensor() {
        let t = Tensor::scalar(3.5);
        assert!(t.shape.is_empty());
        assert_eq!(t.data, vec![3.5]);
    }

    #[test]
    fn load_fails_cleanly_without_artifacts() {
        let err = match Runtime::load("/nonexistent-dir") {
            Err(e) => e,
            Ok(_) => panic!("load should fail"),
        };
        assert!(err.to_string().contains("make artifacts"), "{err}");
    }
}
