//! PJRT backend shim: one import surface for the `xla` crate.
//!
//! The crate builds fully offline by default; the real XLA/PJRT bindings
//! are an *optional* backend behind the `pjrt` cargo feature.  This
//! module is the seam:
//!
//! - with `--features pjrt`, it re-exports the vendored `xla` crate
//!   (patch it in as a path dependency) and [`super::Runtime`] drives
//!   real compiled HLO executables;
//! - without the feature (the default), it provides inert stand-ins with
//!   the same API whose client constructor fails with a clear error, so
//!   every caller compiles and `Runtime::load` reports "backend not
//!   compiled in" instead of link errors.
//!
//! Only the slice of the `xla` API the runtime actually touches is
//! stubbed: client/compile/execute, HLO-text parsing, and f32 literals.

#[cfg(feature = "pjrt")]
pub use xla::*;

#[cfg(not(feature = "pjrt"))]
pub use stub::*;

#[cfg(not(feature = "pjrt"))]
mod stub {
    use std::fmt;

    /// Error type mirroring `xla::Error` for display purposes.
    #[derive(Debug)]
    pub struct Error(pub String);

    impl fmt::Display for Error {
        fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
            write!(f, "{}", self.0)
        }
    }

    impl std::error::Error for Error {}

    fn unavailable<T>() -> Result<T, Error> {
        Err(Error(
            "PJRT backend not compiled in (rebuild with --features pjrt and a vendored \
             `xla` crate)"
                .into(),
        ))
    }

    /// Stand-in for `xla::PjRtClient`.
    pub struct PjRtClient;

    impl PjRtClient {
        pub fn cpu() -> Result<PjRtClient, Error> {
            unavailable()
        }

        pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable, Error> {
            unavailable()
        }
    }

    /// Stand-in for `xla::HloModuleProto`.
    pub struct HloModuleProto;

    impl HloModuleProto {
        pub fn from_text_file(_path: &str) -> Result<HloModuleProto, Error> {
            unavailable()
        }
    }

    /// Stand-in for `xla::XlaComputation`.
    pub struct XlaComputation;

    impl XlaComputation {
        pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
            XlaComputation
        }
    }

    /// Stand-in for `xla::PjRtLoadedExecutable`.
    pub struct PjRtLoadedExecutable;

    impl PjRtLoadedExecutable {
        pub fn execute<L>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>, Error> {
            unavailable()
        }
    }

    /// Stand-in for `xla::PjRtBuffer`.
    pub struct PjRtBuffer;

    impl PjRtBuffer {
        pub fn to_literal_sync(&self) -> Result<Literal, Error> {
            unavailable()
        }
    }

    /// Stand-in for `xla::Literal` (f32 host tensors only).
    pub struct Literal;

    impl Literal {
        pub fn scalar(_v: f32) -> Literal {
            Literal
        }

        pub fn vec1(_data: &[f32]) -> Literal {
            Literal
        }

        pub fn reshape(&self, _dims: &[i64]) -> Result<Literal, Error> {
            unavailable()
        }

        pub fn to_vec<T>(&self) -> Result<Vec<T>, Error> {
            unavailable()
        }

        pub fn to_tuple(self) -> Result<Vec<Literal>, Error> {
            unavailable()
        }
    }

    #[cfg(test)]
    mod tests {
        use super::*;

        #[test]
        fn stub_client_fails_with_clear_message() {
            let err = match PjRtClient::cpu() {
                Err(e) => e,
                Ok(_) => panic!("stub client must not construct"),
            };
            assert!(err.to_string().contains("pjrt"), "{err}");
        }
    }
}
