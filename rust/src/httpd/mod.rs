//! Minimal HTTP/1.1 server + client over `std::net` — the microservice
//! plumbing (paper §4.1: an Apache reverse proxy redirects external
//! HTTPS to the credential server; services speak plain HTTP internally).
//!
//! One OS thread per connection with HTTP/1.1 keep-alive (requests are
//! served sequentially per connection until the peer closes or sends
//! `Connection: close`), bodies framed by `Content-Length`.  Enough
//! surface for the ACAI REST edge (`acai serve`) and the
//! credential-server redirect flow, with hard input limits so a
//! misbehaving client cannot wedge a service.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::error::{AcaiError, Result};
use crate::json::Json;

/// Maximum header block size (16 KiB) and body size (32 MiB).
const MAX_HEADER_BYTES: usize = 16 * 1024;
const MAX_BODY_BYTES: usize = 32 * 1024 * 1024;

/// A parsed HTTP request.
#[derive(Debug, Clone)]
pub struct Request {
    pub method: String,
    pub path: String,
    /// Query string (after '?'), raw.
    pub query: String,
    pub headers: HashMap<String, String>,
    pub body: Vec<u8>,
}

impl Request {
    /// Header lookup, case-insensitive.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.get(&name.to_ascii_lowercase()).map(|s| s.as_str())
    }

    /// Parse the body as JSON.
    pub fn json(&self) -> Result<Json> {
        let text = std::str::from_utf8(&self.body)
            .map_err(|_| AcaiError::invalid("body is not utf-8"))?;
        crate::json::parse(text)
    }
}

/// An HTTP response under construction.
#[derive(Debug, Clone)]
pub struct Response {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Response {
    pub fn new(status: u16) -> Self {
        Self {
            status,
            headers: vec![],
            body: vec![],
        }
    }

    /// 200 with a JSON body.
    pub fn json(value: &Json) -> Self {
        let mut r = Self::new(200);
        r.headers
            .push(("content-type".into(), "application/json".into()));
        r.body = value.encode().into_bytes();
        r
    }

    /// JSON body with an explicit status code.
    pub fn json_with_status(status: u16, value: &Json) -> Self {
        let mut r = Self::new(status);
        r.headers
            .push(("content-type".into(), "application/json".into()));
        r.body = value.encode().into_bytes();
        r
    }

    /// Error response carrying the uniform envelope
    /// `{"error": {"code", "message", "request_id"}}`.  Connection-level
    /// failures (before routing assigns an id) carry `request_id: null`;
    /// the API tier re-emits the envelope with the real id.
    pub fn error(e: &AcaiError) -> Self {
        Self::error_with_request_id(e, None)
    }

    /// The uniform envelope with an explicit request id.
    pub fn error_with_request_id(e: &AcaiError, request_id: Option<&str>) -> Self {
        let rid = match request_id {
            Some(id) => Json::from(id),
            None => Json::Null,
        };
        Self::json_with_status(
            e.status(),
            &Json::obj()
                .field(
                    "error",
                    Json::obj()
                        .field("code", e.code())
                        .field("message", e.to_string())
                        .field("request_id", rid)
                        .build(),
                )
                .build(),
        )
    }

    fn reason(&self) -> &'static str {
        match self.status {
            200 => "OK",
            201 => "Created",
            202 => "Accepted",
            204 => "No Content",
            400 => "Bad Request",
            401 => "Unauthorized",
            403 => "Forbidden",
            404 => "Not Found",
            405 => "Method Not Allowed",
            409 => "Conflict",
            422 => "Unprocessable Entity",
            429 => "Too Many Requests",
            _ => "Internal Server Error",
        }
    }
}

/// Request handler.
pub type Handler = Arc<dyn Fn(&Request) -> Response + Send + Sync>;

/// A running HTTP server; shuts down on drop.
pub struct Server {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind 127.0.0.1 on an ephemeral (or given) port and serve.
    pub fn serve(port: u16, handler: Handler) -> Result<Server> {
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let thread = std::thread::spawn(move || {
            while !stop2.load(Ordering::SeqCst) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let handler = handler.clone();
                        let stop = stop2.clone();
                        std::thread::spawn(move || {
                            let _ = handle_connection(stream, handler, stop);
                        });
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(2));
                    }
                    Err(_) => break,
                }
            }
        });
        Ok(Server {
            addr,
            stop,
            thread: Some(thread),
        })
    }

    /// The bound address.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

fn handle_connection(stream: TcpStream, handler: Handler, stop: Arc<AtomicBool>) -> Result<()> {
    stream.set_read_timeout(Some(std::time::Duration::from_secs(10)))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    loop {
        let (request, http11) = match read_request(&mut reader) {
            Ok(Some(r)) => r,
            // peer closed (or went idle past the read timeout): done
            Ok(None) => return Ok(()),
            Err(e) => {
                // malformed input: answer with the envelope, then close —
                // framing is unknown so the connection cannot be reused
                let _ = write_response(&stream, &Response::error(&e), false);
                return Ok(());
            }
        };
        // a dropped Server must stop serving keep-alive connections too,
        // not just stop accepting new ones
        if stop.load(Ordering::SeqCst) {
            return Ok(());
        }
        // keep-alive is the HTTP/1.1 default; HTTP/1.0 clients must ask
        // for it, and an explicit Connection header always wins
        let keep_alive = match request.header("connection") {
            Some(c) => c.eq_ignore_ascii_case("keep-alive"),
            None => http11,
        };
        let response = handler(&request);
        write_response(&stream, &response, keep_alive)?;
        if !keep_alive {
            return Ok(());
        }
    }
}

/// Read one request off the connection; the `bool` is whether the
/// request line declared HTTP/1.1 (keep-alive default).  `Ok(None)`
/// means the peer closed (or idled out) cleanly between requests.
fn read_request(reader: &mut BufReader<TcpStream>) -> Result<Option<(Request, bool)>> {
    let mut line = String::new();
    match reader.read_line(&mut line) {
        Ok(0) => return Ok(None),
        Ok(_) => {}
        // a timeout/close with NOTHING read is an idle keep-alive
        // connection going away — close silently.  A timeout after
        // partial input is a malformed/stalled request and still gets
        // an error response (read_line keeps the partial bytes in
        // `line` on error).
        Err(e)
            if line.is_empty()
                && matches!(
                    e.kind(),
                    std::io::ErrorKind::WouldBlock
                        | std::io::ErrorKind::TimedOut
                        | std::io::ErrorKind::UnexpectedEof
                        | std::io::ErrorKind::ConnectionReset
                ) =>
        {
            return Ok(None)
        }
        Err(e) => return Err(e.into()),
    }
    let mut parts = line.trim_end().splitn(3, ' ');
    let method = parts
        .next()
        .filter(|m| !m.is_empty())
        .ok_or_else(|| AcaiError::invalid("empty request line"))?
        .to_string();
    let target = parts
        .next()
        .ok_or_else(|| AcaiError::invalid("missing request target"))?;
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (target.to_string(), String::new()),
    };
    let http11 = parts
        .next()
        .map(|v| v.trim().eq_ignore_ascii_case("HTTP/1.1"))
        .unwrap_or(false);

    let mut headers = HashMap::new();
    let mut total = 0usize;
    loop {
        let mut h = String::new();
        if reader.read_line(&mut h)? == 0 {
            // EOF inside the header block is a truncated request, NOT
            // the end-of-headers blank line — never dispatch it
            return Err(AcaiError::invalid("unexpected eof in header block"));
        }
        total += h.len();
        if total > MAX_HEADER_BYTES {
            return Err(AcaiError::invalid("header block too large"));
        }
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((name, value)) = h.split_once(':') {
            headers.insert(name.trim().to_ascii_lowercase(), value.trim().to_string());
        }
    }

    let len: usize = headers
        .get("content-length")
        .map(|v| v.parse().map_err(|_| AcaiError::invalid("bad content-length")))
        .transpose()?
        .unwrap_or(0);
    if len > MAX_BODY_BYTES {
        return Err(AcaiError::invalid("body too large"));
    }
    let mut body = vec![0u8; len];
    reader.read_exact(&mut body)?;
    Ok(Some((
        Request {
            method,
            path,
            query,
            headers,
            body,
        },
        http11,
    )))
}

fn write_response(mut stream: &TcpStream, r: &Response, keep_alive: bool) -> Result<()> {
    let mut head = format!("HTTP/1.1 {} {}\r\n", r.status, r.reason());
    for (k, v) in &r.headers {
        head.push_str(&format!("{k}: {v}\r\n"));
    }
    let conn = if keep_alive { "keep-alive" } else { "close" };
    head.push_str(&format!(
        "content-length: {}\r\nconnection: {conn}\r\n\r\n",
        r.body.len()
    ));
    stream.write_all(head.as_bytes())?;
    stream.write_all(&r.body)?;
    stream.flush()?;
    Ok(())
}

/// A client-side persistent HTTP/1.1 connection: sequential requests
/// reuse one socket (keep-alive), so pollers — e.g. the remote SDK
/// waiting on a job — don't pay a connect + server-thread spawn per
/// request.
pub struct HttpConn {
    addr: SocketAddr,
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl HttpConn {
    pub fn connect(addr: SocketAddr) -> Result<HttpConn> {
        let stream = TcpStream::connect(addr)?;
        stream.set_read_timeout(Some(std::time::Duration::from_secs(30)))?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(HttpConn {
            addr,
            stream,
            reader,
        })
    }

    /// One request/response exchange.  The connection stays usable for
    /// the next request; a server that went away surfaces as an
    /// [`AcaiError::Io`] (callers holding a pooled connection reconnect
    /// on that).
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        headers: &[(&str, &str)],
        body: &[u8],
    ) -> Result<Response> {
        let mut head = format!("{method} {path} HTTP/1.1\r\nhost: {}\r\n", self.addr);
        for (k, v) in headers {
            head.push_str(&format!("{k}: {v}\r\n"));
        }
        head.push_str(&format!("content-length: {}\r\n\r\n", body.len()));
        self.stream.write_all(head.as_bytes())?;
        self.stream.write_all(body)?;
        self.stream.flush()?;
        read_response(&mut self.reader)
    }
}

fn read_response(reader: &mut BufReader<TcpStream>) -> Result<Response> {
    let mut status_line = String::new();
    if reader.read_line(&mut status_line)? == 0 {
        // distinguishable from a malformed status line: pooled callers
        // treat Io as "stale connection, reconnect"
        return Err(AcaiError::Io(std::io::Error::new(
            std::io::ErrorKind::UnexpectedEof,
            "connection closed by server",
        )));
    }
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| AcaiError::invalid(format!("bad status line {status_line:?}")))?;

    let mut headers_out = Vec::new();
    let mut len = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((name, value)) = h.split_once(':') {
            let name = name.trim().to_ascii_lowercase();
            let value = value.trim().to_string();
            if name == "content-length" {
                len = value
                    .parse()
                    .map_err(|_| AcaiError::invalid("bad content-length"))?;
            }
            headers_out.push((name, value));
        }
    }
    let mut body = vec![0u8; len];
    reader.read_exact(&mut body)?;
    Ok(Response {
        status,
        headers: headers_out,
        body,
    })
}

/// Blocking one-shot HTTP client request against a local service
/// (opens and drops a connection; use [`HttpConn`] to poll).
pub fn request(
    addr: SocketAddr,
    method: &str,
    path: &str,
    headers: &[(&str, &str)],
    body: &[u8],
) -> Result<Response> {
    HttpConn::connect(addr)?.request(method, path, headers, body)
}

/// Extract the human message out of the uniform error envelope.
fn envelope_message(v: &Json) -> &str {
    v.get("error")
        .and_then(|e| e.get("message"))
        .and_then(Json::as_str)
        .unwrap_or("?")
}

/// GET helper returning parsed JSON.
pub fn get_json(addr: SocketAddr, path: &str, token: &str) -> Result<Json> {
    let resp = request(addr, "GET", path, &[("x-acai-token", token)], b"")?;
    let text = String::from_utf8_lossy(&resp.body).to_string();
    let v = crate::json::parse(&text)?;
    if resp.status >= 400 {
        return Err(AcaiError::Invalid(format!(
            "HTTP {}: {}",
            resp.status,
            envelope_message(&v)
        )));
    }
    Ok(v)
}

/// POST helper sending + returning JSON.
pub fn post_json(addr: SocketAddr, path: &str, token: &str, body: &Json) -> Result<Json> {
    let resp = request(
        addr,
        "POST",
        path,
        &[("x-acai-token", token), ("content-type", "application/json")],
        body.encode().as_bytes(),
    )?;
    let text = String::from_utf8_lossy(&resp.body).to_string();
    let v = crate::json::parse(&text)?;
    if resp.status >= 400 {
        return Err(AcaiError::Invalid(format!(
            "HTTP {}: {}",
            resp.status,
            envelope_message(&v)
        )));
    }
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn echo_server() -> Server {
        Server::serve(
            0,
            Arc::new(|req: &Request| {
                Response::json(
                    &Json::obj()
                        .field("method", req.method.as_str())
                        .field("path", req.path.as_str())
                        .field("query", req.query.as_str())
                        .field("len", req.body.len())
                        .build(),
                )
            }),
        )
        .unwrap()
    }

    #[test]
    fn request_response_round_trip() {
        let server = echo_server();
        let resp = request(server.addr(), "POST", "/jobs?limit=5", &[], b"hello").unwrap();
        assert_eq!(resp.status, 200);
        let v = crate::json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        assert_eq!(v.get("method").and_then(Json::as_str), Some("POST"));
        assert_eq!(v.get("path").and_then(Json::as_str), Some("/jobs"));
        assert_eq!(v.get("query").and_then(Json::as_str), Some("limit=5"));
        assert_eq!(v.get("len").and_then(Json::as_u64), Some(5));
    }

    #[test]
    fn json_helpers_round_trip() {
        let server = echo_server();
        let v = post_json(server.addr(), "/x", "tok", &Json::obj().field("a", 1.0).build())
            .unwrap();
        assert_eq!(v.get("len").and_then(Json::as_u64), Some(7));
    }

    #[test]
    fn concurrent_requests_are_served() {
        let server = echo_server();
        let addr = server.addr();
        let handles: Vec<_> = (0..8)
            .map(|_| {
                std::thread::spawn(move || {
                    for _ in 0..5 {
                        let r = request(addr, "GET", "/", &[], b"").unwrap();
                        assert_eq!(r.status, 200);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn header_lookup_is_case_insensitive() {
        let server = Server::serve(
            0,
            Arc::new(|req: &Request| {
                let tok = req.header("X-ACAI-Token").unwrap_or("none").to_string();
                Response::json(&Json::obj().field("token", tok).build())
            }),
        )
        .unwrap();
        let resp = request(server.addr(), "GET", "/", &[("x-acai-token", "t-1")], b"").unwrap();
        let v = crate::json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        assert_eq!(v.get("token").and_then(Json::as_str), Some("t-1"));
    }

    #[test]
    fn keep_alive_serves_sequential_requests_on_one_socket() {
        let server = echo_server();
        let mut stream = TcpStream::connect(server.addr()).unwrap();
        stream
            .set_read_timeout(Some(std::time::Duration::from_secs(5)))
            .unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        for i in 0..3 {
            let req = format!("GET /ping{i} HTTP/1.1\r\nhost: x\r\ncontent-length: 0\r\n\r\n");
            stream.write_all(req.as_bytes()).unwrap();
            stream.flush().unwrap();
            // status line
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            assert!(line.starts_with("HTTP/1.1 200"), "{line:?}");
            // headers: find content-length, confirm keep-alive
            let mut len = 0usize;
            let mut keep_alive = false;
            loop {
                let mut h = String::new();
                reader.read_line(&mut h).unwrap();
                let h = h.trim_end().to_ascii_lowercase();
                if h.is_empty() {
                    break;
                }
                if let Some(v) = h.strip_prefix("content-length:") {
                    len = v.trim().parse().unwrap();
                }
                if h == "connection: keep-alive" {
                    keep_alive = true;
                }
            }
            assert!(keep_alive, "round {i} was not keep-alive");
            let mut body = vec![0u8; len];
            reader.read_exact(&mut body).unwrap();
            let v = crate::json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
            assert_eq!(
                v.get("path").and_then(Json::as_str),
                Some(format!("/ping{i}").as_str())
            );
        }
    }

    #[test]
    fn http_conn_reuses_one_connection_for_sequential_requests() {
        let server = echo_server();
        let mut conn = HttpConn::connect(server.addr()).unwrap();
        // if the server closed the socket between requests this would
        // surface as an Io error — success proves keep-alive reuse
        for i in 0..3 {
            let resp = conn.request("GET", &format!("/r{i}"), &[], b"").unwrap();
            assert_eq!(resp.status, 200);
            let v = crate::json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
            assert_eq!(
                v.get("path").and_then(Json::as_str),
                Some(format!("/r{i}").as_str())
            );
        }
    }

    #[test]
    fn connection_close_is_honored() {
        let server = echo_server();
        let mut stream = TcpStream::connect(server.addr()).unwrap();
        stream
            .set_read_timeout(Some(std::time::Duration::from_secs(5)))
            .unwrap();
        stream
            .write_all(b"GET / HTTP/1.1\r\nconnection: close\r\ncontent-length: 0\r\n\r\n")
            .unwrap();
        let mut buf = Vec::new();
        // server must close the socket after the response (read to EOF)
        BufReader::new(&stream).read_to_end(&mut buf).unwrap();
        let text = String::from_utf8_lossy(&buf);
        assert!(text.contains("connection: close"), "{text}");
    }

    #[test]
    fn truncated_header_block_is_rejected_not_dispatched() {
        // a request whose sender dies mid-headers must never reach the
        // handler as a complete (empty-body) request
        let server = echo_server();
        let mut stream = TcpStream::connect(server.addr()).unwrap();
        stream
            .set_read_timeout(Some(std::time::Duration::from_secs(5)))
            .unwrap();
        stream
            .write_all(b"POST /v1/jobs/job-1/kill HTTP/1.1\r\nx-acai-token: t\r\n")
            .unwrap();
        stream.shutdown(std::net::Shutdown::Write).unwrap();
        let mut buf = Vec::new();
        BufReader::new(&stream).read_to_end(&mut buf).unwrap();
        let text = String::from_utf8_lossy(&buf);
        assert!(text.starts_with("HTTP/1.1 400"), "{text}");
    }

    #[test]
    fn http_1_0_defaults_to_close() {
        // an HTTP/1.0 client without a Connection header expects the
        // server to close; keeping the socket open would hang it
        let server = echo_server();
        let mut stream = TcpStream::connect(server.addr()).unwrap();
        stream
            .set_read_timeout(Some(std::time::Duration::from_secs(5)))
            .unwrap();
        stream
            .write_all(b"GET / HTTP/1.0\r\ncontent-length: 0\r\n\r\n")
            .unwrap();
        let mut buf = Vec::new();
        BufReader::new(&stream).read_to_end(&mut buf).unwrap();
        let text = String::from_utf8_lossy(&buf);
        assert!(text.contains("connection: close"), "{text}");
    }

    #[test]
    fn server_shuts_down_on_drop() {
        let addr = {
            let server = echo_server();
            server.addr()
        };
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert!(TcpStream::connect_timeout(&addr, std::time::Duration::from_millis(200)).is_err());
    }
}
