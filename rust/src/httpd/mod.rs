//! Minimal HTTP/1.1 server + client over `std::net` — the microservice
//! plumbing (paper §4.1: an Apache reverse proxy redirects external
//! HTTPS to the credential server; services speak plain HTTP internally).
//!
//! The server is a bounded **worker pool**: a blocking accept thread
//! (which survives transient errors such as EMFILE with bounded
//! backoff) registers connections on a shared ready-queue, and N pool
//! threads pull connections off it to serve pipelined HTTP/1.1
//! keep-alive requests with per-connection reusable read/write
//! buffers.  A connection with no request in flight is parked back on
//! the queue after a short probe, so a stalled or slow-loris client
//! occupies at most one worker for one bounded request timeout while
//! every other connection keeps being served.  Beyond
//! [`ServerConfig::max_connections`] live connections the server sheds
//! new arrivals with a graceful `503` + `retry-after` instead of
//! accepting unboundedly.  Bodies are framed by `Content-Length`, with
//! hard input limits so a misbehaving client cannot wedge a service.
//!
//! [`Server::serve_unpooled`] keeps the original thread-per-connection
//! model alive as the comparison baseline for `benches/perf_api.rs`.

use std::collections::{HashMap, VecDeque};
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::error::{AcaiError, Result};
use crate::json::Json;
use crate::storage::Bytes;

/// Maximum header block size (16 KiB) and body size (32 MiB).
const MAX_HEADER_BYTES: usize = 16 * 1024;
const MAX_BODY_BYTES: usize = 32 * 1024 * 1024;

/// How long a worker waits for the first byte of the next request
/// before parking the connection back on the ready-queue.
const PROBE_TIMEOUT: Duration = Duration::from_millis(2);

/// Once request bytes are in flight the sender gets this long to
/// finish the request; a stall past it closes the connection.
const REQUEST_TIMEOUT: Duration = Duration::from_secs(10);

/// Parked connections with no traffic for this long are dropped.
const IDLE_TIMEOUT: Duration = Duration::from_secs(10);

/// Fairness bound: a worker serves at most this many pipelined
/// requests per turn before the connection goes back to the queue.
const MAX_TURN_REQUESTS: usize = 64;

/// Accept-error backoff bounds (satellite fix: a transient accept
/// failure must not kill the accept thread).
const ACCEPT_BACKOFF_START: Duration = Duration::from_millis(1);
const ACCEPT_BACKOFF_MAX: Duration = Duration::from_millis(100);

/// A parsed HTTP request.
#[derive(Debug, Clone)]
pub struct Request {
    pub method: String,
    pub path: String,
    /// Query string (after '?'), raw.
    pub query: String,
    pub headers: HashMap<String, String>,
    pub body: Vec<u8>,
}

impl Request {
    /// Header lookup, case-insensitive.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.get(&name.to_ascii_lowercase()).map(|s| s.as_str())
    }

    /// Parse the body as JSON.
    pub fn json(&self) -> Result<Json> {
        let text = std::str::from_utf8(&self.body)
            .map_err(|_| AcaiError::invalid("body is not utf-8"))?;
        crate::json::parse(text)
    }
}

/// An HTTP response under construction.
#[derive(Debug, Clone)]
pub struct Response {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
    /// Zero-copy tail segments: shared [`Bytes`] windows written to the
    /// wire after `body` without ever being concatenated.  Content-length
    /// framing covers `body.len() + Σ windows[i].len()`, so handlers can
    /// hand chunk-store windows straight to the connection buffer
    /// (the raw download path) instead of materializing one flat `Vec`.
    pub windows: Vec<Bytes>,
}

impl Response {
    pub fn new(status: u16) -> Self {
        Self {
            status,
            headers: vec![],
            body: vec![],
            windows: vec![],
        }
    }

    /// 200 streaming raw bytes: the segments become the response tail
    /// verbatim (no concatenation, no base64).  Used by the raw
    /// download path to carry chunk-store windows to the socket with
    /// zero deep copies.
    pub fn octet_stream(segments: Vec<Bytes>) -> Self {
        let mut r = Self::new(200);
        r.headers
            .push(("content-type".into(), "application/octet-stream".into()));
        r.windows = segments;
        r
    }

    /// Case-insensitive header lookup (clients inspecting a decoded
    /// response, e.g. `retry-after`).
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers
            .iter()
            .find(|(k, _)| k.eq_ignore_ascii_case(name))
            .map(|(_, v)| v.as_str())
    }

    /// 200 with a JSON body.
    pub fn json(value: &Json) -> Self {
        let mut r = Self::new(200);
        r.headers
            .push(("content-type".into(), "application/json".into()));
        r.body = value.encode().into_bytes();
        r
    }

    /// JSON body with an explicit status code.
    pub fn json_with_status(status: u16, value: &Json) -> Self {
        let mut r = Self::new(status);
        r.headers
            .push(("content-type".into(), "application/json".into()));
        r.body = value.encode().into_bytes();
        r
    }

    /// Error response carrying the uniform envelope
    /// `{"error": {"code", "message", "request_id"}}`.  Connection-level
    /// failures (before routing assigns an id) carry `request_id: null`;
    /// the API tier re-emits the envelope with the real id.
    pub fn error(e: &AcaiError) -> Self {
        Self::error_with_request_id(e, None)
    }

    /// The uniform envelope with an explicit request id.
    pub fn error_with_request_id(e: &AcaiError, request_id: Option<&str>) -> Self {
        let rid = match request_id {
            Some(id) => Json::from(id),
            None => Json::Null,
        };
        Self::json_with_status(
            e.status(),
            &Json::obj()
                .field(
                    "error",
                    Json::obj()
                        .field("code", e.code())
                        .field("message", e.to_string())
                        .field("request_id", rid)
                        .build(),
                )
                .build(),
        )
    }

    fn reason(&self) -> &'static str {
        match self.status {
            200 => "OK",
            201 => "Created",
            202 => "Accepted",
            204 => "No Content",
            400 => "Bad Request",
            401 => "Unauthorized",
            403 => "Forbidden",
            404 => "Not Found",
            405 => "Method Not Allowed",
            409 => "Conflict",
            422 => "Unprocessable Entity",
            429 => "Too Many Requests",
            503 => "Service Unavailable",
            _ => "Internal Server Error",
        }
    }
}

/// Request handler.
pub type Handler = Arc<dyn Fn(&Request) -> Response + Send + Sync>;

/// Worker-pool sizing and admission bounds for [`Server::serve_with`].
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Pool threads.  `0` means `available_parallelism` (floored at 2
    /// so one stalled client can never starve the whole pool).
    pub workers: usize,
    /// Live-connection cap; arrivals beyond it are shed with a
    /// graceful `503` + `retry-after` instead of queueing unboundedly.
    pub max_connections: usize,
}

impl Default for ServerConfig {
    fn default() -> Self {
        Self {
            workers: 0,
            max_connections: 256,
        }
    }
}

impl ServerConfig {
    fn pool_size(&self) -> usize {
        let n = if self.workers > 0 {
            self.workers
        } else {
            std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(4)
        };
        n.max(2)
    }
}

/// A server-side connection owned by the worker pool between turns.
/// Read/write buffers live here so keep-alive requests reuse them
/// instead of reallocating per request; the live-connection count is
/// tied to this struct's lifetime.
struct Conn {
    stream: TcpStream,
    reader: BufReader<TcpStream>,
    /// Coalesced response bytes (status line + headers + body).
    wbuf: Vec<u8>,
    /// Request-body buffer, reclaimed after each dispatch.
    body_buf: Vec<u8>,
    /// Request-line buffer (probe may park a partial line here).
    line: String,
    last_active: Instant,
    /// Fresh from accept or just served: worth a short blocking probe.
    /// Parked connections get a nonblocking peek instead.
    hot: bool,
    live: Arc<AtomicUsize>,
}

impl Conn {
    fn new(stream: TcpStream, live: Arc<AtomicUsize>) -> Result<Conn> {
        let reader = BufReader::new(stream.try_clone()?);
        live.fetch_add(1, Ordering::SeqCst);
        Ok(Conn {
            stream,
            reader,
            wbuf: Vec::with_capacity(512),
            body_buf: Vec::new(),
            line: String::new(),
            last_active: Instant::now(),
            hot: true,
            live,
        })
    }

    /// Nonblocking readiness check for a parked connection.
    fn readiness(&mut self) -> Readiness {
        // pipelined bytes already buffered count as ready
        if !self.reader.buffer().is_empty() {
            return Readiness::Ready;
        }
        if self.stream.set_nonblocking(true).is_err() {
            return Readiness::Closed;
        }
        let mut byte = [0u8; 1];
        let peeked = self.stream.peek(&mut byte);
        if self.stream.set_nonblocking(false).is_err() {
            return Readiness::Closed;
        }
        match peeked {
            Ok(0) => Readiness::Closed,
            Ok(_) => Readiness::Ready,
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => Readiness::NotYet,
            Err(_) => Readiness::Closed,
        }
    }
}

impl Drop for Conn {
    fn drop(&mut self) {
        self.live.fetch_sub(1, Ordering::SeqCst);
    }
}

enum Readiness {
    Ready,
    NotYet,
    Closed,
}

/// Shared ready-queue between the accept thread and the worker pool.
#[derive(Default)]
struct ConnQueue {
    inner: Mutex<VecDeque<Conn>>,
    ready: Condvar,
}

impl ConnQueue {
    fn push(&self, conn: Conn) {
        self.inner.lock().unwrap().push_back(conn);
        self.ready.notify_one();
    }

    /// Blocking pop; `None` once the server is stopping.
    fn pop(&self, stop: &AtomicBool) -> Option<Conn> {
        let mut q = self.inner.lock().unwrap();
        loop {
            if stop.load(Ordering::SeqCst) {
                return None;
            }
            if let Some(c) = q.pop_front() {
                return Some(c);
            }
            let (guard, _) = self
                .ready
                .wait_timeout(q, Duration::from_millis(10))
                .unwrap();
            q = guard;
        }
    }
}

/// A running HTTP server; shuts down on drop.
pub struct Server {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    shed: Arc<AtomicU64>,
    live: Arc<AtomicUsize>,
    queue: Option<Arc<ConnQueue>>,
    threads: Vec<std::thread::JoinHandle<()>>,
    workers: usize,
    max_connections: usize,
}

impl Server {
    /// Bind 127.0.0.1 on an ephemeral (or given) port and serve with
    /// the default worker-pool configuration.
    pub fn serve(port: u16, handler: Handler) -> Result<Server> {
        Self::serve_with(port, handler, ServerConfig::default())
    }

    /// Worker-pool server with explicit sizing/admission bounds.
    pub fn serve_with(port: u16, handler: Handler, config: ServerConfig) -> Result<Server> {
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let shed = Arc::new(AtomicU64::new(0));
        let live = Arc::new(AtomicUsize::new(0));
        let queue = Arc::new(ConnQueue::default());
        let max_connections = config.max_connections.max(1);

        let mut threads = Vec::with_capacity(config.pool_size() + 1);
        for _ in 0..config.pool_size() {
            let queue = queue.clone();
            let handler = handler.clone();
            let stop = stop.clone();
            threads.push(std::thread::spawn(move || {
                worker_loop(&queue, &handler, &stop);
            }));
        }
        {
            let stop = stop.clone();
            let shed = shed.clone();
            let live = live.clone();
            let queue = queue.clone();
            threads.push(std::thread::spawn(move || {
                accept_loop(&listener, &stop, |stream| {
                    if live.load(Ordering::SeqCst) >= max_connections {
                        shed.fetch_add(1, Ordering::SeqCst);
                        shed_connection(stream);
                        return;
                    }
                    if let Ok(conn) = Conn::new(stream, live.clone()) {
                        queue.push(conn);
                    }
                });
            }));
        }
        Ok(Server {
            addr,
            stop,
            shed,
            live,
            queue: Some(queue),
            threads,
            workers: config.pool_size(),
            max_connections,
        })
    }

    /// The original thread-per-connection server, kept as the
    /// comparison baseline for `benches/perf_api.rs`.
    pub fn serve_unpooled(port: u16, handler: Handler) -> Result<Server> {
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let thread = std::thread::spawn(move || {
            accept_loop(&listener, &stop2, |stream| {
                let handler = handler.clone();
                let stop = stop2.clone();
                std::thread::spawn(move || {
                    let _ = handle_connection(stream, handler, stop);
                });
            });
        });
        Ok(Server {
            addr,
            stop,
            shed: Arc::new(AtomicU64::new(0)),
            live: Arc::new(AtomicUsize::new(0)),
            queue: None,
            threads: vec![thread],
            workers: 0,
            max_connections: usize::MAX,
        })
    }

    /// The bound address.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// Connections shed with 503 because the live cap was reached.
    pub fn shed_count(&self) -> u64 {
        self.shed.load(Ordering::SeqCst)
    }

    /// Connections currently registered with the worker pool.
    pub fn live_connections(&self) -> usize {
        self.live.load(Ordering::SeqCst)
    }

    /// Pool threads serving requests (0 for the unpooled baseline).
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// The live-connection cap arrivals are shed against.
    pub fn max_connections(&self) -> usize {
        self.max_connections
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        // nudge the blocking accept thread awake so it observes `stop`
        let _ = TcpStream::connect(self.addr);
        if let Some(q) = &self.queue {
            q.ready.notify_all();
        }
        for t in self.threads.drain(..) {
            let _ = t.join();
        }
    }
}

/// Blocking accept loop shared by both server flavors.  Transient
/// accept errors (EMFILE, ECONNABORTED, ...) back off and retry with a
/// bounded delay — only shutdown exits the loop.
fn accept_loop(listener: &TcpListener, stop: &AtomicBool, mut on_conn: impl FnMut(TcpStream)) {
    let mut backoff = ACCEPT_BACKOFF_START;
    while !stop.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => {
                backoff = ACCEPT_BACKOFF_START;
                // the shutdown nudge connection lands here
                if stop.load(Ordering::SeqCst) {
                    return;
                }
                on_conn(stream);
            }
            Err(_) => {
                std::thread::sleep(backoff);
                backoff = (backoff * 2).min(ACCEPT_BACKOFF_MAX);
            }
        }
    }
}

/// The graceful shed response: the uniform envelope (code `exhausted`)
/// under 503 + `retry-after`, so SDK clients can rehydrate the typed
/// error and back off.
fn overload_response() -> Response {
    let mut r = Response::error(&AcaiError::Exhausted(
        "server is at its connection limit; retry shortly".into(),
    ));
    r.status = 503;
    r.headers.push(("retry-after".into(), "1".into()));
    r
}

/// Write the 503 and close without slamming the door: drain whatever
/// the client already sent first, so the close does not RST the
/// response out of the peer's receive buffer.
fn shed_connection(stream: TcpStream) {
    let _ = write_response(&stream, &overload_response(), false);
    let _ = stream.shutdown(std::net::Shutdown::Write);
    let _ = stream.set_read_timeout(Some(Duration::from_millis(10)));
    let mut sink = [0u8; 1024];
    let mut r = &stream;
    while matches!(r.read(&mut sink), Ok(n) if n > 0) {}
}

fn worker_loop(queue: &ConnQueue, handler: &Handler, stop: &AtomicBool) {
    while let Some(mut conn) = queue.pop(stop) {
        if !conn.hot {
            match conn.readiness() {
                Readiness::Ready => {}
                Readiness::Closed => continue,
                Readiness::NotYet => {
                    if conn.last_active.elapsed() > IDLE_TIMEOUT {
                        continue; // idle too long: drop the connection
                    }
                    queue.push(conn);
                    // pace the idle-poll so a queue of parked
                    // connections doesn't busy-spin the pool
                    std::thread::sleep(Duration::from_millis(2));
                    continue;
                }
            }
        }
        match serve_turn(&mut conn, handler, stop) {
            Turn::Requeue => {
                conn.hot = false;
                queue.push(conn);
            }
            Turn::Close => {}
        }
    }
}

enum Turn {
    Requeue,
    Close,
}

/// Serve up to [`MAX_TURN_REQUESTS`] pipelined requests on one
/// connection, then hand it back to the queue.  A short probe decides
/// whether a request is in flight; only once bytes arrive does the
/// worker commit to the full request timeout.
fn serve_turn(conn: &mut Conn, handler: &Handler, stop: &AtomicBool) -> Turn {
    for _ in 0..MAX_TURN_REQUESTS {
        if conn.stream.set_read_timeout(Some(PROBE_TIMEOUT)).is_err() {
            return Turn::Close;
        }
        conn.line.clear();
        let probe = probe_request_line(&mut conn.reader, &mut conn.line);
        match probe {
            Probe::Closed => return Turn::Close,
            Probe::Idle => return Turn::Requeue,
            Probe::Err(e) => {
                let _ = write_response_into(conn, &Response::error(&e), false);
                return Turn::Close;
            }
            Probe::Line | Probe::Partial => {}
        }
        // request bytes are in flight: commit to the full timeout
        if conn.stream.set_read_timeout(Some(REQUEST_TIMEOUT)).is_err() {
            return Turn::Close;
        }
        if matches!(probe, Probe::Partial) {
            match conn.reader.read_line(&mut conn.line) {
                Ok(_) => {}
                Err(_) => {
                    let e = AcaiError::invalid("stalled mid-request");
                    let _ = write_response_into(conn, &Response::error(&e), false);
                    return Turn::Close;
                }
            }
        }
        let (request, http11) =
            match finish_request(&mut conn.reader, &conn.line, &mut conn.body_buf) {
                Ok(r) => r,
                Err(e) => {
                    // framing is unknown: answer, then close
                    let _ = write_response_into(conn, &Response::error(&e), false);
                    return Turn::Close;
                }
            };
        // a dropped Server must stop serving keep-alive connections
        // too, not just stop accepting new ones
        if stop.load(Ordering::SeqCst) {
            return Turn::Close;
        }
        // keep-alive is the HTTP/1.1 default; HTTP/1.0 clients must ask
        // for it, and an explicit Connection header always wins
        let keep_alive = match request.header("connection") {
            Some(c) => c.eq_ignore_ascii_case("keep-alive"),
            None => http11,
        };
        let response = handler.as_ref()(&request);
        let ok = write_response_into(conn, &response, keep_alive).is_ok();
        // reclaim the body allocation for the next request
        conn.body_buf = request.body;
        conn.body_buf.clear();
        if !ok || !keep_alive {
            return Turn::Close;
        }
        conn.last_active = Instant::now();
    }
    Turn::Requeue // fairness: let other connections have a worker
}

/// What a short read of the request line produced.
enum Probe {
    /// A complete request line is in the buffer.
    Line,
    /// Some bytes arrived but the line is not finished yet.
    Partial,
    /// Nothing at all: the connection is just idle.
    Idle,
    /// The peer is gone (clean close between requests).
    Closed,
    /// Malformed traffic that deserves an error response.
    Err(AcaiError),
}

fn probe_request_line(reader: &mut BufReader<TcpStream>, line: &mut String) -> Probe {
    match reader.read_line(line) {
        Ok(0) => Probe::Closed,
        Ok(_) => Probe::Line,
        // read_line keeps partial bytes in `line` on error, which is
        // how a parked partial request survives to the next attempt
        Err(e) => {
            let timeoutish = matches!(
                e.kind(),
                std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
            );
            let gone = matches!(
                e.kind(),
                std::io::ErrorKind::UnexpectedEof
                    | std::io::ErrorKind::ConnectionReset
                    | std::io::ErrorKind::BrokenPipe
            );
            if timeoutish && line.is_empty() {
                Probe::Idle
            } else if timeoutish {
                Probe::Partial
            } else if gone && line.is_empty() {
                Probe::Closed
            } else if gone {
                Probe::Err(AcaiError::invalid("unexpected eof in request line"))
            } else {
                Probe::Err(e.into())
            }
        }
    }
}

/// Parse the rest of a request whose request line is already in
/// `line`; the body is read into the reusable `body_buf` and moved
/// into the returned [`Request`].  The `bool` is whether the request
/// declared HTTP/1.1 (keep-alive default).
fn finish_request(
    reader: &mut BufReader<TcpStream>,
    line: &str,
    body_buf: &mut Vec<u8>,
) -> Result<(Request, bool)> {
    let mut parts = line.trim_end().splitn(3, ' ');
    let method = parts
        .next()
        .filter(|m| !m.is_empty())
        .ok_or_else(|| AcaiError::invalid("empty request line"))?
        .to_string();
    let target = parts
        .next()
        .ok_or_else(|| AcaiError::invalid("missing request target"))?;
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (target.to_string(), String::new()),
    };
    let http11 = parts
        .next()
        .map(|v| v.trim().eq_ignore_ascii_case("HTTP/1.1"))
        .unwrap_or(false);

    let mut headers = HashMap::new();
    let mut total = 0usize;
    loop {
        let mut h = String::new();
        if reader.read_line(&mut h)? == 0 {
            // EOF inside the header block is a truncated request, NOT
            // the end-of-headers blank line — never dispatch it
            return Err(AcaiError::invalid("unexpected eof in header block"));
        }
        total += h.len();
        if total > MAX_HEADER_BYTES {
            return Err(AcaiError::invalid("header block too large"));
        }
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((name, value)) = h.split_once(':') {
            headers.insert(name.trim().to_ascii_lowercase(), value.trim().to_string());
        }
    }

    let len: usize = headers
        .get("content-length")
        .map(|v| v.parse().map_err(|_| AcaiError::invalid("bad content-length")))
        .transpose()?
        .unwrap_or(0);
    if len > MAX_BODY_BYTES {
        return Err(AcaiError::invalid("body too large"));
    }
    body_buf.clear();
    body_buf.resize(len, 0);
    reader.read_exact(body_buf)?;
    let body = std::mem::take(body_buf);
    Ok((
        Request {
            method,
            path,
            query,
            headers,
            body,
        },
        http11,
    ))
}

/// Thread-per-connection serving loop (the unpooled baseline).
fn handle_connection(stream: TcpStream, handler: Handler, stop: Arc<AtomicBool>) -> Result<()> {
    stream.set_read_timeout(Some(REQUEST_TIMEOUT))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    loop {
        let (request, http11) = match read_request(&mut reader) {
            Ok(Some(r)) => r,
            // peer closed (or went idle past the read timeout): done
            Ok(None) => return Ok(()),
            Err(e) => {
                // malformed input: answer with the envelope, then close —
                // framing is unknown so the connection cannot be reused
                let _ = write_response(&stream, &Response::error(&e), false);
                return Ok(());
            }
        };
        if stop.load(Ordering::SeqCst) {
            return Ok(());
        }
        let keep_alive = match request.header("connection") {
            Some(c) => c.eq_ignore_ascii_case("keep-alive"),
            None => http11,
        };
        let response = handler(&request);
        write_response(&stream, &response, keep_alive)?;
        if !keep_alive {
            return Ok(());
        }
    }
}

/// Read one request off the connection; the `bool` is whether the
/// request line declared HTTP/1.1 (keep-alive default).  `Ok(None)`
/// means the peer closed (or idled out) cleanly between requests.
fn read_request(reader: &mut BufReader<TcpStream>) -> Result<Option<(Request, bool)>> {
    let mut line = String::new();
    match probe_request_line(reader, &mut line) {
        Probe::Closed | Probe::Idle => return Ok(None),
        // a timeout after partial input is a malformed/stalled request
        // and still gets an error response
        Probe::Partial => return Err(AcaiError::invalid("stalled mid-request")),
        Probe::Err(e) => return Err(e),
        Probe::Line => {}
    }
    let mut body_buf = Vec::new();
    finish_request(reader, &line, &mut body_buf).map(Some)
}

/// Encode status line + headers + framing headers + body into one
/// contiguous buffer (single syscall per response instead of three).
fn encode_response(buf: &mut Vec<u8>, r: &Response, keep_alive: bool) {
    buf.clear();
    let conn = if keep_alive { "keep-alive" } else { "close" };
    // Vec<u8> writes are infallible
    let _ = write!(buf, "HTTP/1.1 {} {}\r\n", r.status, r.reason());
    for (k, v) in &r.headers {
        let _ = write!(buf, "{k}: {v}\r\n");
    }
    let windows_len: usize = r.windows.iter().map(Bytes::len).sum();
    let _ = write!(
        buf,
        "content-length: {}\r\nconnection: {conn}\r\n\r\n",
        r.body.len() + windows_len
    );
    buf.extend_from_slice(&r.body);
    for w in &r.windows {
        buf.extend_from_slice(w);
    }
}

/// Coalesced response write through the connection's reusable buffer.
fn write_response_into(conn: &mut Conn, r: &Response, keep_alive: bool) -> Result<()> {
    let mut wbuf = std::mem::take(&mut conn.wbuf);
    encode_response(&mut wbuf, r, keep_alive);
    let outcome = conn
        .stream
        .write_all(&wbuf)
        .and_then(|_| conn.stream.flush());
    conn.wbuf = wbuf;
    outcome?;
    Ok(())
}

/// One-shot coalesced response write (unpooled/shed paths).
fn write_response(mut stream: &TcpStream, r: &Response, keep_alive: bool) -> Result<()> {
    let mut buf = Vec::with_capacity(256 + r.body.len());
    encode_response(&mut buf, r, keep_alive);
    stream.write_all(&buf)?;
    stream.flush()?;
    Ok(())
}

/// A client-side persistent HTTP/1.1 connection: sequential requests
/// reuse one socket (keep-alive), so pollers — e.g. the remote SDK
/// waiting on a job — don't pay a connect + server-thread spawn per
/// request.
pub struct HttpConn {
    addr: SocketAddr,
    stream: TcpStream,
    reader: BufReader<TcpStream>,
}

impl HttpConn {
    pub fn connect(addr: SocketAddr) -> Result<HttpConn> {
        let stream = TcpStream::connect(addr)?;
        stream.set_read_timeout(Some(std::time::Duration::from_secs(30)))?;
        let reader = BufReader::new(stream.try_clone()?);
        Ok(HttpConn {
            addr,
            stream,
            reader,
        })
    }

    /// One request/response exchange.  The connection stays usable for
    /// the next request; a server that went away surfaces as an
    /// [`AcaiError::Io`] (callers holding a pooled connection reconnect
    /// on that).
    pub fn request(
        &mut self,
        method: &str,
        path: &str,
        headers: &[(&str, &str)],
        body: &[u8],
    ) -> Result<Response> {
        let mut head = format!("{method} {path} HTTP/1.1\r\nhost: {}\r\n", self.addr);
        for (k, v) in headers {
            head.push_str(&format!("{k}: {v}\r\n"));
        }
        head.push_str(&format!("content-length: {}\r\n\r\n", body.len()));
        self.stream.write_all(head.as_bytes())?;
        self.stream.write_all(body)?;
        self.stream.flush()?;
        read_response(&mut self.reader)
    }
}

fn read_response(reader: &mut BufReader<TcpStream>) -> Result<Response> {
    let mut status_line = String::new();
    if reader.read_line(&mut status_line)? == 0 {
        // distinguishable from a malformed status line: pooled callers
        // treat Io as "stale connection, reconnect"
        return Err(AcaiError::Io(std::io::Error::new(
            std::io::ErrorKind::UnexpectedEof,
            "connection closed by server",
        )));
    }
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| AcaiError::invalid(format!("bad status line {status_line:?}")))?;

    let mut headers_out = Vec::new();
    let mut len = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((name, value)) = h.split_once(':') {
            let name = name.trim().to_ascii_lowercase();
            let value = value.trim().to_string();
            if name == "content-length" {
                len = value
                    .parse()
                    .map_err(|_| AcaiError::invalid("bad content-length"))?;
            }
            headers_out.push((name, value));
        }
    }
    let mut body = vec![0u8; len];
    reader.read_exact(&mut body)?;
    Ok(Response {
        status,
        headers: headers_out,
        body,
        windows: vec![],
    })
}

/// Blocking one-shot HTTP client request against a local service
/// (opens and drops a connection; use [`HttpConn`] to poll).
pub fn request(
    addr: SocketAddr,
    method: &str,
    path: &str,
    headers: &[(&str, &str)],
    body: &[u8],
) -> Result<Response> {
    HttpConn::connect(addr)?.request(method, path, headers, body)
}

/// Extract the human message out of the uniform error envelope.
fn envelope_message(v: &Json) -> &str {
    v.get("error")
        .and_then(|e| e.get("message"))
        .and_then(Json::as_str)
        .unwrap_or("?")
}

/// GET helper returning parsed JSON.
pub fn get_json(addr: SocketAddr, path: &str, token: &str) -> Result<Json> {
    let resp = request(addr, "GET", path, &[("x-acai-token", token)], b"")?;
    let text = String::from_utf8_lossy(&resp.body).to_string();
    let v = crate::json::parse(&text)?;
    if resp.status >= 400 {
        return Err(AcaiError::Invalid(format!(
            "HTTP {}: {}",
            resp.status,
            envelope_message(&v)
        )));
    }
    Ok(v)
}

/// POST helper sending + returning JSON.
pub fn post_json(addr: SocketAddr, path: &str, token: &str, body: &Json) -> Result<Json> {
    let resp = request(
        addr,
        "POST",
        path,
        &[("x-acai-token", token), ("content-type", "application/json")],
        body.encode().as_bytes(),
    )?;
    let text = String::from_utf8_lossy(&resp.body).to_string();
    let v = crate::json::parse(&text)?;
    if resp.status >= 400 {
        return Err(AcaiError::Invalid(format!(
            "HTTP {}: {}",
            resp.status,
            envelope_message(&v)
        )));
    }
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn echo_handler() -> Handler {
        Arc::new(|req: &Request| {
            Response::json(
                &Json::obj()
                    .field("method", req.method.as_str())
                    .field("path", req.path.as_str())
                    .field("query", req.query.as_str())
                    .field("len", req.body.len())
                    .build(),
            )
        })
    }

    fn echo_server() -> Server {
        Server::serve(0, echo_handler()).unwrap()
    }

    #[test]
    fn request_response_round_trip() {
        let server = echo_server();
        let resp = request(server.addr(), "POST", "/jobs?limit=5", &[], b"hello").unwrap();
        assert_eq!(resp.status, 200);
        let v = crate::json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        assert_eq!(v.get("method").and_then(Json::as_str), Some("POST"));
        assert_eq!(v.get("path").and_then(Json::as_str), Some("/jobs"));
        assert_eq!(v.get("query").and_then(Json::as_str), Some("limit=5"));
        assert_eq!(v.get("len").and_then(Json::as_u64), Some(5));
    }

    #[test]
    fn json_helpers_round_trip() {
        let server = echo_server();
        let v = post_json(server.addr(), "/x", "tok", &Json::obj().field("a", 1.0).build())
            .unwrap();
        assert_eq!(v.get("len").and_then(Json::as_u64), Some(7));
    }

    #[test]
    fn concurrent_requests_are_served() {
        let server = echo_server();
        let addr = server.addr();
        let handles: Vec<_> = (0..8)
            .map(|_| {
                std::thread::spawn(move || {
                    for _ in 0..5 {
                        let r = request(addr, "GET", "/", &[], b"").unwrap();
                        assert_eq!(r.status, 200);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn header_lookup_is_case_insensitive() {
        let server = Server::serve(
            0,
            Arc::new(|req: &Request| {
                let tok = req.header("X-ACAI-Token").unwrap_or("none").to_string();
                Response::json(&Json::obj().field("token", tok).build())
            }),
        )
        .unwrap();
        let resp = request(server.addr(), "GET", "/", &[("x-acai-token", "t-1")], b"").unwrap();
        let v = crate::json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        assert_eq!(v.get("token").and_then(Json::as_str), Some("t-1"));
    }

    #[test]
    fn keep_alive_serves_sequential_requests_on_one_socket() {
        let server = echo_server();
        let mut stream = TcpStream::connect(server.addr()).unwrap();
        stream
            .set_read_timeout(Some(std::time::Duration::from_secs(5)))
            .unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        for i in 0..3 {
            let req = format!("GET /ping{i} HTTP/1.1\r\nhost: x\r\ncontent-length: 0\r\n\r\n");
            stream.write_all(req.as_bytes()).unwrap();
            stream.flush().unwrap();
            // status line
            let mut line = String::new();
            reader.read_line(&mut line).unwrap();
            assert!(line.starts_with("HTTP/1.1 200"), "{line:?}");
            // headers: find content-length, confirm keep-alive
            let mut len = 0usize;
            let mut keep_alive = false;
            loop {
                let mut h = String::new();
                reader.read_line(&mut h).unwrap();
                let h = h.trim_end().to_ascii_lowercase();
                if h.is_empty() {
                    break;
                }
                if let Some(v) = h.strip_prefix("content-length:") {
                    len = v.trim().parse().unwrap();
                }
                if h == "connection: keep-alive" {
                    keep_alive = true;
                }
            }
            assert!(keep_alive, "round {i} was not keep-alive");
            let mut body = vec![0u8; len];
            reader.read_exact(&mut body).unwrap();
            let v = crate::json::parse(std::str::from_utf8(&body).unwrap()).unwrap();
            assert_eq!(
                v.get("path").and_then(Json::as_str),
                Some(format!("/ping{i}").as_str())
            );
        }
    }

    #[test]
    fn http_conn_reuses_one_connection_for_sequential_requests() {
        let server = echo_server();
        let mut conn = HttpConn::connect(server.addr()).unwrap();
        // if the server closed the socket between requests this would
        // surface as an Io error — success proves keep-alive reuse
        for i in 0..3 {
            let resp = conn.request("GET", &format!("/r{i}"), &[], b"").unwrap();
            assert_eq!(resp.status, 200);
            let v = crate::json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
            assert_eq!(
                v.get("path").and_then(Json::as_str),
                Some(format!("/r{i}").as_str())
            );
        }
    }

    #[test]
    fn connection_close_is_honored() {
        let server = echo_server();
        let mut stream = TcpStream::connect(server.addr()).unwrap();
        stream
            .set_read_timeout(Some(std::time::Duration::from_secs(5)))
            .unwrap();
        stream
            .write_all(b"GET / HTTP/1.1\r\nconnection: close\r\ncontent-length: 0\r\n\r\n")
            .unwrap();
        let mut buf = Vec::new();
        // server must close the socket after the response (read to EOF)
        BufReader::new(&stream).read_to_end(&mut buf).unwrap();
        let text = String::from_utf8_lossy(&buf);
        assert!(text.contains("connection: close"), "{text}");
    }

    #[test]
    fn truncated_header_block_is_rejected_not_dispatched() {
        // a request whose sender dies mid-headers must never reach the
        // handler as a complete (empty-body) request
        let server = echo_server();
        let mut stream = TcpStream::connect(server.addr()).unwrap();
        stream
            .set_read_timeout(Some(std::time::Duration::from_secs(5)))
            .unwrap();
        stream
            .write_all(b"POST /v1/jobs/job-1/kill HTTP/1.1\r\nx-acai-token: t\r\n")
            .unwrap();
        stream.shutdown(std::net::Shutdown::Write).unwrap();
        let mut buf = Vec::new();
        BufReader::new(&stream).read_to_end(&mut buf).unwrap();
        let text = String::from_utf8_lossy(&buf);
        assert!(text.starts_with("HTTP/1.1 400"), "{text}");
    }

    #[test]
    fn http_1_0_defaults_to_close() {
        // an HTTP/1.0 client without a Connection header expects the
        // server to close; keeping the socket open would hang it
        let server = echo_server();
        let mut stream = TcpStream::connect(server.addr()).unwrap();
        stream
            .set_read_timeout(Some(std::time::Duration::from_secs(5)))
            .unwrap();
        stream
            .write_all(b"GET / HTTP/1.0\r\ncontent-length: 0\r\n\r\n")
            .unwrap();
        let mut buf = Vec::new();
        BufReader::new(&stream).read_to_end(&mut buf).unwrap();
        let text = String::from_utf8_lossy(&buf);
        assert!(text.contains("connection: close"), "{text}");
    }

    #[test]
    fn server_shuts_down_on_drop() {
        let addr = {
            let server = echo_server();
            server.addr()
        };
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert!(TcpStream::connect_timeout(&addr, std::time::Duration::from_millis(200)).is_err());
    }

    #[test]
    fn pipelined_requests_are_answered_in_order() {
        let server = echo_server();
        let mut stream = TcpStream::connect(server.addr()).unwrap();
        stream
            .set_read_timeout(Some(std::time::Duration::from_secs(5)))
            .unwrap();
        // two requests in one write: both must be answered, in order
        stream
            .write_all(
                b"GET /first HTTP/1.1\r\ncontent-length: 0\r\n\r\n\
                  GET /second HTTP/1.1\r\ncontent-length: 0\r\n\r\n",
            )
            .unwrap();
        let mut reader = BufReader::new(stream.try_clone().unwrap());
        for expect in ["/first", "/second"] {
            let resp = read_response(&mut reader).unwrap();
            assert_eq!(resp.status, 200);
            let v = crate::json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
            assert_eq!(v.get("path").and_then(Json::as_str), Some(expect));
        }
    }

    #[test]
    fn over_capacity_connections_are_shed_with_503() {
        let server = Server::serve_with(
            0,
            echo_handler(),
            ServerConfig {
                workers: 2,
                max_connections: 1,
            },
        )
        .unwrap();
        // a completed request proves the first connection is registered
        let mut keep = HttpConn::connect(server.addr()).unwrap();
        assert_eq!(keep.request("GET", "/", &[], b"").unwrap().status, 200);
        // the second connection is over the cap: graceful 503 envelope
        let mut second = HttpConn::connect(server.addr()).unwrap();
        let resp = second.request("GET", "/", &[], b"").unwrap();
        assert_eq!(resp.status, 503);
        assert!(resp
            .headers
            .iter()
            .any(|(k, v)| k == "retry-after" && v == "1"));
        let v = crate::json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        assert_eq!(
            v.get("error").and_then(|e| e.get("code")).and_then(Json::as_str),
            Some("exhausted")
        );
        assert_eq!(server.shed_count(), 1);
        // the in-cap connection keeps working
        assert_eq!(keep.request("GET", "/again", &[], b"").unwrap().status, 200);
    }

    #[test]
    fn unpooled_server_round_trips_and_keeps_alive() {
        let server = Server::serve_unpooled(0, echo_handler()).unwrap();
        let mut conn = HttpConn::connect(server.addr()).unwrap();
        for i in 0..3 {
            let resp = conn.request("GET", &format!("/u{i}"), &[], b"").unwrap();
            assert_eq!(resp.status, 200);
            let v = crate::json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
            assert_eq!(
                v.get("path").and_then(Json::as_str),
                Some(format!("/u{i}").as_str())
            );
        }
    }
}
