//! Minimal HTTP/1.1 server + client over `std::net` — the microservice
//! plumbing (paper §4.1: an Apache reverse proxy redirects external
//! HTTPS to the credential server; services speak plain HTTP internally).
//!
//! One OS thread per connection, `Connection: close` semantics, bodies
//! framed by `Content-Length`.  Enough surface for the ACAI REST edge
//! (`acai serve`) and the credential-server redirect flow, with hard
//! input limits so a misbehaving client cannot wedge a service.

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::error::{AcaiError, Result};
use crate::json::Json;

/// Maximum header block size (16 KiB) and body size (32 MiB).
const MAX_HEADER_BYTES: usize = 16 * 1024;
const MAX_BODY_BYTES: usize = 32 * 1024 * 1024;

/// A parsed HTTP request.
#[derive(Debug, Clone)]
pub struct Request {
    pub method: String,
    pub path: String,
    /// Query string (after '?'), raw.
    pub query: String,
    pub headers: HashMap<String, String>,
    pub body: Vec<u8>,
}

impl Request {
    /// Header lookup, case-insensitive.
    pub fn header(&self, name: &str) -> Option<&str> {
        self.headers.get(&name.to_ascii_lowercase()).map(|s| s.as_str())
    }

    /// Parse the body as JSON.
    pub fn json(&self) -> Result<Json> {
        let text = std::str::from_utf8(&self.body)
            .map_err(|_| AcaiError::invalid("body is not utf-8"))?;
        crate::json::parse(text)
    }
}

/// An HTTP response under construction.
#[derive(Debug, Clone)]
pub struct Response {
    pub status: u16,
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Response {
    pub fn new(status: u16) -> Self {
        Self {
            status,
            headers: vec![],
            body: vec![],
        }
    }

    /// 200 with a JSON body.
    pub fn json(value: &Json) -> Self {
        let mut r = Self::new(200);
        r.headers
            .push(("content-type".into(), "application/json".into()));
        r.body = value.encode().into_bytes();
        r
    }

    /// Error response with a JSON `{"error": ...}` body.
    pub fn error(e: &AcaiError) -> Self {
        let mut r = Self::new(e.status());
        r.headers
            .push(("content-type".into(), "application/json".into()));
        r.body = Json::obj()
            .field("error", e.to_string())
            .build()
            .encode()
            .into_bytes();
        r
    }

    fn reason(&self) -> &'static str {
        match self.status {
            200 => "OK",
            201 => "Created",
            204 => "No Content",
            400 => "Bad Request",
            401 => "Unauthorized",
            403 => "Forbidden",
            404 => "Not Found",
            409 => "Conflict",
            422 => "Unprocessable Entity",
            429 => "Too Many Requests",
            _ => "Internal Server Error",
        }
    }
}

/// Request handler.
pub type Handler = Arc<dyn Fn(&Request) -> Response + Send + Sync>;

/// A running HTTP server; shuts down on drop.
pub struct Server {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    thread: Option<std::thread::JoinHandle<()>>,
}

impl Server {
    /// Bind 127.0.0.1 on an ephemeral (or given) port and serve.
    pub fn serve(port: u16, handler: Handler) -> Result<Server> {
        let listener = TcpListener::bind(("127.0.0.1", port))?;
        let addr = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let thread = std::thread::spawn(move || {
            while !stop2.load(Ordering::SeqCst) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        let handler = handler.clone();
                        std::thread::spawn(move || {
                            let _ = handle_connection(stream, handler);
                        });
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(2));
                    }
                    Err(_) => break,
                }
            }
        });
        Ok(Server {
            addr,
            stop,
            thread: Some(thread),
        })
    }

    /// The bound address.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }
}

impl Drop for Server {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::SeqCst);
        if let Some(t) = self.thread.take() {
            let _ = t.join();
        }
    }
}

fn handle_connection(stream: TcpStream, handler: Handler) -> Result<()> {
    stream.set_read_timeout(Some(std::time::Duration::from_secs(10)))?;
    let mut reader = BufReader::new(stream.try_clone()?);
    let request = match read_request(&mut reader) {
        Ok(r) => r,
        Err(e) => {
            write_response(&stream, &Response::error(&e))?;
            return Ok(());
        }
    };
    let response = handler(&request);
    write_response(&stream, &response)
}

fn read_request(reader: &mut BufReader<TcpStream>) -> Result<Request> {
    let mut line = String::new();
    reader.read_line(&mut line)?;
    let mut parts = line.trim_end().splitn(3, ' ');
    let method = parts
        .next()
        .filter(|m| !m.is_empty())
        .ok_or_else(|| AcaiError::invalid("empty request line"))?
        .to_string();
    let target = parts
        .next()
        .ok_or_else(|| AcaiError::invalid("missing request target"))?;
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p.to_string(), q.to_string()),
        None => (target.to_string(), String::new()),
    };

    let mut headers = HashMap::new();
    let mut total = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        total += h.len();
        if total > MAX_HEADER_BYTES {
            return Err(AcaiError::invalid("header block too large"));
        }
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((name, value)) = h.split_once(':') {
            headers.insert(name.trim().to_ascii_lowercase(), value.trim().to_string());
        }
    }

    let len: usize = headers
        .get("content-length")
        .map(|v| v.parse().map_err(|_| AcaiError::invalid("bad content-length")))
        .transpose()?
        .unwrap_or(0);
    if len > MAX_BODY_BYTES {
        return Err(AcaiError::invalid("body too large"));
    }
    let mut body = vec![0u8; len];
    reader.read_exact(&mut body)?;
    Ok(Request {
        method,
        path,
        query,
        headers,
        body,
    })
}

fn write_response(mut stream: &TcpStream, r: &Response) -> Result<()> {
    let mut head = format!("HTTP/1.1 {} {}\r\n", r.status, r.reason());
    for (k, v) in &r.headers {
        head.push_str(&format!("{k}: {v}\r\n"));
    }
    head.push_str(&format!("content-length: {}\r\nconnection: close\r\n\r\n", r.body.len()));
    stream.write_all(head.as_bytes())?;
    stream.write_all(&r.body)?;
    stream.flush()?;
    Ok(())
}

/// Blocking HTTP client request against a local service.
pub fn request(
    addr: SocketAddr,
    method: &str,
    path: &str,
    headers: &[(&str, &str)],
    body: &[u8],
) -> Result<Response> {
    let mut stream = TcpStream::connect(addr)?;
    stream.set_read_timeout(Some(std::time::Duration::from_secs(30)))?;
    let mut head = format!("{method} {path} HTTP/1.1\r\nhost: {addr}\r\n");
    for (k, v) in headers {
        head.push_str(&format!("{k}: {v}\r\n"));
    }
    head.push_str(&format!("content-length: {}\r\n\r\n", body.len()));
    stream.write_all(head.as_bytes())?;
    stream.write_all(body)?;
    stream.flush()?;

    let mut reader = BufReader::new(stream);
    let mut status_line = String::new();
    reader.read_line(&mut status_line)?;
    let status: u16 = status_line
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| AcaiError::invalid(format!("bad status line {status_line:?}")))?;

    let mut headers_out = Vec::new();
    let mut len = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h)?;
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((name, value)) = h.split_once(':') {
            let name = name.trim().to_ascii_lowercase();
            let value = value.trim().to_string();
            if name == "content-length" {
                len = value
                    .parse()
                    .map_err(|_| AcaiError::invalid("bad content-length"))?;
            }
            headers_out.push((name, value));
        }
    }
    let mut body = vec![0u8; len];
    reader.read_exact(&mut body)?;
    Ok(Response {
        status,
        headers: headers_out,
        body,
    })
}

/// GET helper returning parsed JSON.
pub fn get_json(addr: SocketAddr, path: &str, token: &str) -> Result<Json> {
    let resp = request(addr, "GET", path, &[("x-acai-token", token)], b"")?;
    let text = String::from_utf8_lossy(&resp.body).to_string();
    let v = crate::json::parse(&text)?;
    if resp.status >= 400 {
        return Err(AcaiError::Invalid(format!(
            "HTTP {}: {}",
            resp.status,
            v.get("error").and_then(Json::as_str).unwrap_or("?")
        )));
    }
    Ok(v)
}

/// POST helper sending + returning JSON.
pub fn post_json(addr: SocketAddr, path: &str, token: &str, body: &Json) -> Result<Json> {
    let resp = request(
        addr,
        "POST",
        path,
        &[("x-acai-token", token), ("content-type", "application/json")],
        body.encode().as_bytes(),
    )?;
    let text = String::from_utf8_lossy(&resp.body).to_string();
    let v = crate::json::parse(&text)?;
    if resp.status >= 400 {
        return Err(AcaiError::Invalid(format!(
            "HTTP {}: {}",
            resp.status,
            v.get("error").and_then(Json::as_str).unwrap_or("?")
        )));
    }
    Ok(v)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn echo_server() -> Server {
        Server::serve(
            0,
            Arc::new(|req: &Request| {
                Response::json(
                    &Json::obj()
                        .field("method", req.method.as_str())
                        .field("path", req.path.as_str())
                        .field("query", req.query.as_str())
                        .field("len", req.body.len())
                        .build(),
                )
            }),
        )
        .unwrap()
    }

    #[test]
    fn request_response_round_trip() {
        let server = echo_server();
        let resp = request(server.addr(), "POST", "/jobs?limit=5", &[], b"hello").unwrap();
        assert_eq!(resp.status, 200);
        let v = crate::json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        assert_eq!(v.get("method").and_then(Json::as_str), Some("POST"));
        assert_eq!(v.get("path").and_then(Json::as_str), Some("/jobs"));
        assert_eq!(v.get("query").and_then(Json::as_str), Some("limit=5"));
        assert_eq!(v.get("len").and_then(Json::as_u64), Some(5));
    }

    #[test]
    fn json_helpers_round_trip() {
        let server = echo_server();
        let v = post_json(server.addr(), "/x", "tok", &Json::obj().field("a", 1.0).build())
            .unwrap();
        assert_eq!(v.get("len").and_then(Json::as_u64), Some(7));
    }

    #[test]
    fn concurrent_requests_are_served() {
        let server = echo_server();
        let addr = server.addr();
        let handles: Vec<_> = (0..8)
            .map(|_| {
                std::thread::spawn(move || {
                    for _ in 0..5 {
                        let r = request(addr, "GET", "/", &[], b"").unwrap();
                        assert_eq!(r.status, 200);
                    }
                })
            })
            .collect();
        for h in handles {
            h.join().unwrap();
        }
    }

    #[test]
    fn header_lookup_is_case_insensitive() {
        let server = Server::serve(
            0,
            Arc::new(|req: &Request| {
                let tok = req.header("X-ACAI-Token").unwrap_or("none").to_string();
                Response::json(&Json::obj().field("token", tok).build())
            }),
        )
        .unwrap();
        let resp = request(server.addr(), "GET", "/", &[("x-acai-token", "t-1")], b"").unwrap();
        let v = crate::json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        assert_eq!(v.get("token").and_then(Json::as_str), Some("t-1"));
    }

    #[test]
    fn server_shuts_down_on_drop() {
        let addr = {
            let server = echo_server();
            server.addr()
        };
        std::thread::sleep(std::time::Duration::from_millis(20));
        assert!(TcpStream::connect_timeout(&addr, std::time::Duration::from_millis(200)).is_err());
    }
}
