//! Virtual clock for the cluster simulator.
//!
//! The paper's evaluation sweeps hundreds of cloud jobs whose *billed*
//! runtimes span hours; the simulator runs them in milliseconds of wall
//! time by advancing a shared virtual clock between discrete events
//! (container completions).  Real compute (PJRT MLP training) supplies the
//! numerics; the clock supplies the billing time — see DESIGN.md.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// A shared virtual clock, in virtual seconds (f64 stored as micros).
#[derive(Debug, Clone, Default)]
pub struct SimClock {
    micros: Arc<AtomicU64>,
}

impl SimClock {
    pub fn new() -> Self {
        Self::default()
    }

    /// Current virtual time, seconds.
    pub fn now(&self) -> f64 {
        self.micros.load(Ordering::SeqCst) as f64 / 1e6
    }

    /// Advance by `secs` (must be non-negative).
    pub fn advance(&self, secs: f64) {
        assert!(secs >= 0.0, "cannot advance clock backwards");
        self.micros
            .fetch_add((secs * 1e6).round() as u64, Ordering::SeqCst);
    }

    /// Advance to an absolute time, if it is in the future.
    pub fn advance_to(&self, t: f64) {
        let target = (t * 1e6).round() as u64;
        let mut cur = self.micros.load(Ordering::SeqCst);
        while target > cur {
            match self.micros.compare_exchange(
                cur,
                target,
                Ordering::SeqCst,
                Ordering::SeqCst,
            ) {
                Ok(_) => return,
                Err(actual) => cur = actual,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn starts_at_zero() {
        assert_eq!(SimClock::new().now(), 0.0);
    }

    #[test]
    fn advances_monotonically() {
        let c = SimClock::new();
        c.advance(1.5);
        assert!((c.now() - 1.5).abs() < 1e-9);
        c.advance(0.25);
        assert!((c.now() - 1.75).abs() < 1e-9);
    }

    #[test]
    fn advance_to_never_goes_backwards() {
        let c = SimClock::new();
        c.advance_to(10.0);
        c.advance_to(5.0);
        assert!((c.now() - 10.0).abs() < 1e-9);
        c.advance_to(12.0);
        assert!((c.now() - 12.0).abs() < 1e-9);
    }

    #[test]
    fn clones_share_time() {
        let a = SimClock::new();
        let b = a.clone();
        a.advance(3.0);
        assert!((b.now() - 3.0).abs() < 1e-9);
    }

    #[test]
    #[should_panic]
    fn negative_advance_panics() {
        SimClock::new().advance(-1.0);
    }
}
