//! Credential server: users, projects, token authentication (paper §3.1,
//! §4.1).
//!
//! The credential server is the only client-facing endpoint.  Every
//! request carries a user token (generated at user creation); the server
//! authenticates it, resolves the (project, user) pair, and redirects the
//! request to the right internal service.  Authorization rules:
//!
//! - a **global administrator** creates projects;
//! - each project has an **administrator user** who creates users in it;
//! - project members can access everything inside their project (the
//!   paper defers finer-grained ACLs to future work, §7.1.1).

use std::collections::HashMap;
use std::sync::{Arc, Mutex};

use crate::error::{AcaiError, Result};
use crate::ids::{IdGen, ProjectId, UserId};
use crate::prng::Rng;

/// An authenticated identity.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Identity {
    pub project: ProjectId,
    pub user: UserId,
    pub is_project_admin: bool,
}

#[derive(Debug, Clone)]
struct UserRecord {
    id: UserId,
    project: ProjectId,
    name: String,
    token: String,
    is_project_admin: bool,
}

#[derive(Debug, Clone)]
struct ProjectRecord {
    #[allow(dead_code)]
    id: ProjectId,
    name: String,
    /// Fair-share weight: the project's slice of cluster capacity
    /// relative to its peers (scheduler DRF — see
    /// [`crate::engine::Scheduler`]).  Default 1.0.
    weight: f64,
}

#[derive(Default)]
struct Inner {
    projects: HashMap<ProjectId, ProjectRecord>,
    project_names: HashMap<String, ProjectId>,
    users: HashMap<UserId, UserRecord>,
    tokens: HashMap<String, UserId>,
}

/// The credential server.
#[derive(Clone)]
pub struct CredentialServer {
    inner: Arc<Mutex<Inner>>,
    ids: Arc<IdGen>,
    rng: Arc<Mutex<Rng>>,
    /// The global administrator token (configured at deployment).
    root_token: String,
}

impl CredentialServer {
    pub fn new(seed: u64) -> Self {
        let mut rng = Rng::new(seed);
        let root_token = Self::fresh_token(&mut rng);
        Self {
            inner: Arc::new(Mutex::new(Inner::default())),
            ids: Arc::new(IdGen::new()),
            rng: Arc::new(Mutex::new(rng)),
            root_token,
        }
    }

    fn fresh_token(rng: &mut Rng) -> String {
        format!("tok-{:016x}{:016x}", rng.next_u64(), rng.next_u64())
    }

    /// The deployment's global-admin token.
    pub fn root_token(&self) -> &str {
        &self.root_token
    }

    /// Create a project (global admin only).  Returns the project id and
    /// the token of its administrator user.
    pub fn create_project(
        &self,
        root_token: &str,
        name: &str,
        admin_user: &str,
    ) -> Result<(ProjectId, String)> {
        if root_token != self.root_token {
            return Err(AcaiError::Forbidden(
                "only the global administrator can create projects".into(),
            ));
        }
        if name.is_empty() {
            return Err(AcaiError::invalid("project name must be non-empty"));
        }
        let mut inner = self.inner.lock().unwrap();
        if inner.project_names.contains_key(name) {
            return Err(AcaiError::conflict(format!("project {name:?} exists")));
        }
        let pid = ProjectId(self.ids.next());
        inner.projects.insert(
            pid,
            ProjectRecord {
                id: pid,
                name: name.to_string(),
                weight: 1.0,
            },
        );
        inner.project_names.insert(name.to_string(), pid);
        drop(inner);
        let token = self.insert_user(pid, admin_user, true)?;
        Ok((pid, token))
    }

    fn insert_user(&self, project: ProjectId, name: &str, admin: bool) -> Result<String> {
        let mut inner = self.inner.lock().unwrap();
        if inner
            .users
            .values()
            .any(|u| u.project == project && u.name == name)
        {
            return Err(AcaiError::conflict(format!(
                "user {name:?} exists in {project}"
            )));
        }
        let uid = UserId(self.ids.next());
        let token = Self::fresh_token(&mut self.rng.lock().unwrap());
        inner.users.insert(
            uid,
            UserRecord {
                id: uid,
                project,
                name: name.to_string(),
                token: token.clone(),
                is_project_admin: admin,
            },
        );
        inner.tokens.insert(token.clone(), uid);
        Ok(token)
    }

    /// Create a user under the caller's project (project admin only).
    pub fn create_user(&self, admin_token: &str, name: &str) -> Result<String> {
        let caller = self.authenticate(admin_token)?;
        if !caller.is_project_admin {
            return Err(AcaiError::Forbidden(
                "only the project administrator can create users".into(),
            ));
        }
        self.insert_user(caller.project, name, false)
    }

    /// Authenticate a token into an [`Identity`] — the redirect step the
    /// paper's Figure 7 shows in front of every internal service.
    pub fn authenticate(&self, token: &str) -> Result<Identity> {
        let inner = self.inner.lock().unwrap();
        let uid = inner
            .tokens
            .get(token)
            .ok_or_else(|| AcaiError::Unauthorized("unknown token".into()))?;
        let user = &inner.users[uid];
        Ok(Identity {
            project: user.project,
            user: user.id,
            is_project_admin: user.is_project_admin,
        })
    }

    /// Rotate a user's token (invalidate the old one).
    pub fn rotate_token(&self, token: &str) -> Result<String> {
        let id = self.authenticate(token)?;
        let mut inner = self.inner.lock().unwrap();
        let fresh = Self::fresh_token(&mut self.rng.lock().unwrap());
        inner.tokens.remove(token);
        inner.tokens.insert(fresh.clone(), id.user);
        inner.users.get_mut(&id.user).unwrap().token = fresh.clone();
        Ok(fresh)
    }

    /// Resolve a project by name.
    pub fn project_by_name(&self, name: &str) -> Option<ProjectId> {
        self.inner.lock().unwrap().project_names.get(name).copied()
    }

    /// Set a project's fair-share weight (global admin only).  Returns
    /// the project id so the caller can mirror the weight into the
    /// scheduler.
    pub fn set_project_weight(
        &self,
        root_token: &str,
        name: &str,
        weight: f64,
    ) -> Result<ProjectId> {
        if root_token != self.root_token {
            return Err(AcaiError::Forbidden(
                "only the global administrator can set project weights".into(),
            ));
        }
        if !(weight.is_finite() && weight > 0.0) {
            return Err(AcaiError::invalid(format!(
                "weight must be a positive finite number, got {weight}"
            )));
        }
        let mut inner = self.inner.lock().unwrap();
        let pid = *inner
            .project_names
            .get(name)
            .ok_or_else(|| AcaiError::not_found(format!("project {name:?}")))?;
        inner.projects.get_mut(&pid).unwrap().weight = weight;
        Ok(pid)
    }

    /// A project's fair-share weight (1.0 if unknown).
    pub fn project_weight(&self, project: ProjectId) -> f64 {
        self.inner
            .lock()
            .unwrap()
            .projects
            .get(&project)
            .map(|p| p.weight)
            .unwrap_or(1.0)
    }

    /// Display name of a user (dashboard/metadata "creator" field).
    pub fn user_name(&self, user: UserId) -> Option<String> {
        self.inner
            .lock()
            .unwrap()
            .users
            .get(&user)
            .map(|u| u.name.clone())
    }

    /// Project display name.
    pub fn project_name(&self, project: ProjectId) -> Option<String> {
        self.inner
            .lock()
            .unwrap()
            .projects
            .get(&project)
            .map(|p| p.name.clone())
    }

    /// Users of a project (admin-visible listing).
    pub fn list_users(&self, token: &str) -> Result<Vec<(UserId, String)>> {
        let id = self.authenticate(token)?;
        let inner = self.inner.lock().unwrap();
        let mut users: Vec<_> = inner
            .users
            .values()
            .filter(|u| u.project == id.project)
            .map(|u| (u.id, u.name.clone()))
            .collect();
        users.sort_by_key(|(id, _)| *id);
        Ok(users)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn server() -> CredentialServer {
        CredentialServer::new(1)
    }

    #[test]
    fn project_creation_requires_root() {
        let s = server();
        assert!(s.create_project("bad-token", "nlp", "alice").is_err());
        let root = s.root_token().to_string();
        let (pid, admin_tok) = s.create_project(&root, "nlp", "alice").unwrap();
        let id = s.authenticate(&admin_tok).unwrap();
        assert_eq!(id.project, pid);
        assert!(id.is_project_admin);
    }

    #[test]
    fn user_creation_requires_project_admin() {
        let s = server();
        let root = s.root_token().to_string();
        let (_pid, admin) = s.create_project(&root, "nlp", "alice").unwrap();
        let bob = s.create_user(&admin, "bob").unwrap();
        // bob is not an admin
        let err = s.create_user(&bob, "carol").unwrap_err();
        assert_eq!(err.status(), 403);
    }

    #[test]
    fn members_share_a_project() {
        let s = server();
        let root = s.root_token().to_string();
        let (pid, admin) = s.create_project(&root, "nlp", "alice").unwrap();
        let bob = s.create_user(&admin, "bob").unwrap();
        assert_eq!(s.authenticate(&bob).unwrap().project, pid);
        assert_eq!(s.list_users(&admin).unwrap().len(), 2);
    }

    #[test]
    fn duplicate_names_rejected() {
        let s = server();
        let root = s.root_token().to_string();
        let (_p, admin) = s.create_project(&root, "nlp", "alice").unwrap();
        assert!(s.create_project(&root, "nlp", "x").is_err());
        s.create_user(&admin, "bob").unwrap();
        assert!(s.create_user(&admin, "bob").is_err());
    }

    #[test]
    fn project_weight_is_root_guarded_and_validated() {
        let s = server();
        let root = s.root_token().to_string();
        let (pid, _admin) = s.create_project(&root, "nlp", "alice").unwrap();
        assert_eq!(s.project_weight(pid), 1.0);
        assert_eq!(s.set_project_weight("bad", "nlp", 4.0).unwrap_err().status(), 403);
        assert_eq!(s.set_project_weight(&root, "none", 4.0).unwrap_err().status(), 404);
        assert_eq!(s.set_project_weight(&root, "nlp", 0.0).unwrap_err().status(), 400);
        assert_eq!(s.set_project_weight(&root, "nlp", 4.0).unwrap(), pid);
        assert_eq!(s.project_weight(pid), 4.0);
    }

    #[test]
    fn bad_tokens_are_unauthorized() {
        let s = server();
        assert_eq!(s.authenticate("nope").unwrap_err().status(), 401);
    }

    #[test]
    fn token_rotation_invalidates_old() {
        let s = server();
        let root = s.root_token().to_string();
        let (_p, admin) = s.create_project(&root, "nlp", "alice").unwrap();
        let fresh = s.rotate_token(&admin).unwrap();
        assert!(s.authenticate(&admin).is_err());
        assert!(s.authenticate(&fresh).is_ok());
    }

    #[test]
    fn projects_are_isolated_namespaces() {
        let s = server();
        let root = s.root_token().to_string();
        let (p1, a1) = s.create_project(&root, "nlp", "alice").unwrap();
        let (p2, a2) = s.create_project(&root, "vision", "alice").unwrap();
        assert_ne!(p1, p2);
        // same user name in two projects is fine
        assert_eq!(s.authenticate(&a1).unwrap().project, p1);
        assert_eq!(s.authenticate(&a2).unwrap().project, p2);
    }

    #[test]
    fn tokens_are_unique() {
        let s = server();
        let root = s.root_token().to_string();
        let (_p, admin) = s.create_project(&root, "nlp", "alice").unwrap();
        let mut tokens = std::collections::HashSet::new();
        tokens.insert(admin.clone());
        for i in 0..50 {
            let t = s.create_user(&admin, &format!("u{i}")).unwrap();
            assert!(tokens.insert(t), "duplicate token");
        }
    }
}
