//! Cloud pricing model (paper §4.3, Fig 11).
//!
//! Each vCPU and each MB of memory is billed separately.  Unit prices
//! *ramp linearly* with the amount provisioned — 2/3 of the anchor price
//! at the minimum config (0.5 vCPU / 512 MB) up to 4/3 at the maximum
//! (8 vCPU / 8192 MB) — to discourage vertical scaling:
//!
//! ```text
//! unit_cpu(c) = CPU_ANCHOR * (2/3 + (2/3) * (c   - 0.5) / 7.5 )
//! unit_mem(m) = MEM_ANCHOR * (2/3 + (2/3) * (m   - 512) / 7680)
//! cost(c, m, t) = (unit_cpu(c) * c + unit_mem(m) * m) * t
//! ```
//!
//! The anchors are calibrated so the paper's Table 2 baseline reproduces
//! exactly: an n1-standard-2-shaped job (2 vCPU, 7.5 GB) running 64.6 s
//! costs $0.09765.  (The paper says the anchors derive from GCP N1
//! us-east1 prices, but its own table values imply a different absolute
//! scale — we match the tables, which is what the benches reproduce.
//! See EXPERIMENTS.md.)

use crate::cluster::ResourceConfig;

/// $/(vCPU·second) at the anchor (scale factor 1.0).
pub const CPU_ANCHOR: f64 = 5.2702e-4;
/// $/(MB·second) at the anchor.
pub const MEM_ANCHOR: f64 = 6.7511e-8;

/// $/request at the API edge (the per-call half of the multi-tenant
/// billing surface; modeled on public cloud API-gateway pricing,
/// ~$0.40 per million requests).
pub const REQUEST_ANCHOR: f64 = 4.0e-7;
/// $/byte transferred through the API edge (request + response bodies;
/// ~$0.09 per GB).
pub const BYTE_ANCHOR: f64 = 9.0e-11;

/// vCPU range endpoints (paper §4.3).
pub const CPU_MIN: f64 = 0.5;
pub const CPU_MAX: f64 = 8.0;
/// Memory range endpoints, MB.
pub const MEM_MIN: f64 = 512.0;
pub const MEM_MAX: f64 = 8192.0;

/// The pricing model. A value type so experiments can ablate it.
#[derive(Debug, Clone, Copy)]
pub struct PricingModel {
    pub cpu_anchor: f64,
    pub mem_anchor: f64,
}

impl Default for PricingModel {
    fn default() -> Self {
        Self {
            cpu_anchor: CPU_ANCHOR,
            mem_anchor: MEM_ANCHOR,
        }
    }
}

impl PricingModel {
    /// The sliding unit-price factor: 2/3 at `lo`, 4/3 at `hi`.
    fn ramp(x: f64, lo: f64, hi: f64) -> f64 {
        let frac = ((x - lo) / (hi - lo)).clamp(0.0, 1.0);
        (2.0 / 3.0) + (2.0 / 3.0) * frac
    }

    /// Unit price per vCPU-second at `c` provisioned vCPUs (Fig 11 left).
    pub fn unit_cpu(&self, vcpus: f64) -> f64 {
        self.cpu_anchor * Self::ramp(vcpus, CPU_MIN, CPU_MAX)
    }

    /// Unit price per MB-second at `m` provisioned MB (Fig 11 right).
    pub fn unit_mem(&self, mem_mb: f64) -> f64 {
        self.mem_anchor * Self::ramp(mem_mb, MEM_MIN, MEM_MAX)
    }

    /// Dollar rate per second for a configuration (the paper's
    /// `g = μ_c·c·f + μ_m·m·f` with the runtime factored out).
    pub fn rate(&self, res: ResourceConfig) -> f64 {
        self.unit_cpu(res.vcpus) * res.vcpus
            + self.unit_mem(res.mem_mb as f64) * res.mem_mb as f64
    }

    /// Total cost of running `res` for `runtime_secs` (Table 2/3 formula).
    pub fn cost(&self, res: ResourceConfig, runtime_secs: f64) -> f64 {
        self.rate(res) * runtime_secs
    }

    /// API-edge usage cost: per-request plus per-transferred-byte (the
    /// tenant billing surface behind `GET /v1/tenant`).
    pub fn api_cost(&self, requests: u64, bytes: u64) -> f64 {
        requests as f64 * REQUEST_ANCHOR + bytes as f64 * BYTE_ANCHOR
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const BASELINE: ResourceConfig = ResourceConfig {
        vcpus: 2.0,
        mem_mb: 7680, // n1-standard-2: 7.5 GB
    };

    #[test]
    fn ramp_hits_paper_endpoints() {
        let p = PricingModel::default();
        // 2/3 of anchor at the minimum, 4/3 at the maximum (Fig 11)
        assert!((p.unit_cpu(0.5) / p.cpu_anchor - 2.0 / 3.0).abs() < 1e-12);
        assert!((p.unit_cpu(8.0) / p.cpu_anchor - 4.0 / 3.0).abs() < 1e-12);
        assert!((p.unit_mem(512.0) / p.mem_anchor - 2.0 / 3.0).abs() < 1e-12);
        assert!((p.unit_mem(8192.0) / p.mem_anchor - 4.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn ramp_is_linear_in_between() {
        let p = PricingModel::default();
        let mid = p.unit_cpu((0.5 + 8.0) / 2.0) / p.cpu_anchor;
        assert!((mid - 1.0).abs() < 1e-12, "{mid}");
    }

    #[test]
    fn baseline_cost_matches_table2() {
        // Paper Table 2: 2 vCPU + 7.5 GB for 64.6 s costs $0.09765.
        let p = PricingModel::default();
        let cost = p.cost(BASELINE, 64.6);
        assert!(
            (cost - 0.09765).abs() < 0.0005,
            "baseline cost {cost} != paper 0.09765"
        );
    }

    #[test]
    fn table3_auto_config_cost_matches() {
        // Paper Table 3: 2.5 vCPU + 512 MB for 52.6 s costs $0.05975.
        let p = PricingModel::default();
        let cost = p.cost(ResourceConfig::new(2.5, 512), 52.6);
        assert!(
            (cost - 0.05975).abs() < 0.002,
            "auto config cost {cost} != paper 0.05975"
        );
    }

    #[test]
    fn more_resources_cost_superlinearly_more() {
        let p = PricingModel::default();
        let r1 = p.rate(ResourceConfig::new(1.0, 1024));
        let r2 = p.rate(ResourceConfig::new(2.0, 2048));
        assert!(r2 > 2.0 * r1, "vertical scaling must be penalised");
    }

    #[test]
    fn api_cost_prices_requests_and_bytes() {
        let p = PricingModel::default();
        assert_eq!(p.api_cost(0, 0), 0.0);
        // a million requests ≈ $0.40, a GB transferred ≈ $0.09
        assert!((p.api_cost(1_000_000, 0) - 0.40).abs() < 1e-9);
        assert!((p.api_cost(0, 1_000_000_000) - 0.09).abs() < 1e-9);
        // linear + additive
        let one = p.api_cost(1, 100);
        assert!((p.api_cost(2, 200) - 2.0 * one).abs() < 1e-18);
    }

    #[test]
    fn cost_is_linear_in_time() {
        let p = PricingModel::default();
        let c1 = p.cost(BASELINE, 10.0);
        let c2 = p.cost(BASELINE, 20.0);
        assert!((c2 - 2.0 * c1).abs() < 1e-12);
    }
}
