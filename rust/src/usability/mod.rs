//! Usability-study workflow simulator (paper §5.2, Tables 5–6).
//!
//! The paper times a human running a hyperparameter sweep **manually on
//! GCP** (control) vs **through the ACAI SDK** (treatment).  We cannot
//! rerun humans, so the study is reproduced as a workflow-step model
//! with the machine time coming from *actually running the sweep* on the
//! platform:
//!
//! - **code development** and **experiment tracking** times are per-step
//!   human constants (calibrated per round from the paper's tables; the
//!   treatment is cheaper because the SDK replaces glue code, and the
//!   log parser + metadata queries replace manual bookkeeping);
//! - **resource deployment** is a manual-only cost (ACAI auto-provisions);
//! - **machine time** is the makespan of the real job batch executed by
//!   the engine on the virtual clock, with the control paying an extra
//!   manual launch gap per job (the human baby-sitting each run).
//!
//! The bench target prints the same category rows as Tables 5/6.

use std::sync::Arc;

use crate::cluster::ResourceConfig;
use crate::engine::JobSpec;
use crate::error::Result;
use crate::ids::{ProjectId, UserId};
use crate::platform::Acai;

/// Human-step constants for one study round (minutes).
#[derive(Debug, Clone, Copy)]
pub struct StudyParams {
    pub code_dev_manual_min: f64,
    pub code_dev_acai_min: f64,
    pub deploy_manual_min: f64,
    /// Bookkeeping per job.
    pub track_manual_per_job_min: f64,
    pub track_acai_per_job_min: f64,
    /// Manual launch gap per job (control only).
    pub launch_manual_per_job_min: f64,
    /// Billing rate for the control's always-on VM ($/min).
    pub vm_rate_per_min: f64,
}

/// Round 1: frame-level speech classification with MLPs — 16 jobs
/// (paper §8.1.1; constants calibrated to Table 5).
pub fn round1_params() -> StudyParams {
    StudyParams {
        code_dev_manual_min: 21.47,
        code_dev_acai_min: 16.65,
        deploy_manual_min: 14.37,
        track_manual_per_job_min: 8.52 / 16.0,
        track_acai_per_job_min: 5.07 / 16.0,
        launch_manual_per_job_min: 1.13,
        vm_rate_per_min: 0.0247,
    }
}

/// Round 2: Porto Seguro safe-driver prediction with XGBoost — 72 jobs
/// (paper §8.1.2; constants calibrated to Table 6).
pub fn round2_params() -> StudyParams {
    StudyParams {
        code_dev_manual_min: 4.75,
        code_dev_acai_min: 2.23,
        deploy_manual_min: 7.43,
        track_manual_per_job_min: 12.6 / 72.0,
        track_acai_per_job_min: 1.07 / 72.0,
        launch_manual_per_job_min: 0.03,
        vm_rate_per_min: 0.003,
    }
}

/// The MLP hyperparameter grid of Table 8 → 16 training commands.
/// (layers × context are the numeric axes; batch-norm/dropout fold into
/// the remaining binary axes — 3·3·2·2 = 36 in the table, the paper runs
/// the 16-job subset its Table 5 reports.)
pub fn round1_commands() -> Vec<String> {
    let mut out = Vec::new();
    for layers in [5, 7, 9] {
        for context in [5, 10, 15] {
            for dropout in [0, 1] {
                out.push(format!(
                    "python train_mnist.py --epoch 8 --scale 64 --layers {layers} \
                     --context {context} --dropout {dropout}"
                ));
            }
        }
    }
    out.truncate(16);
    out
}

/// The XGBoost grid of Table 9 → 3·3·2·2 = 36 combos × 2 seeds = 72 jobs.
pub fn round2_commands() -> Vec<String> {
    let mut out = Vec::new();
    for depth in [2, 6, 10] {
        for trees in [200, 400, 600] {
            for subsample in ["0.8", "1"] {
                for booster in [0, 1] {
                    for seed in [0, 1] {
                        out.push(format!(
                            "python xgb_train.py --max-depth {depth} --n-estimators {trees} \
                             --subsample {subsample} --booster {booster} --seed {seed}"
                        ));
                    }
                }
            }
        }
    }
    out.truncate(72);
    out
}

/// One category row of Table 5/6.
#[derive(Debug, Clone)]
pub struct CategoryRow {
    pub category: &'static str,
    pub control_min: f64,
    pub treatment_min: f64,
}

/// The study outcome.
#[derive(Debug, Clone)]
pub struct StudyReport {
    pub jobs: usize,
    pub rows: Vec<CategoryRow>,
    pub control_total_min: f64,
    pub treatment_total_min: f64,
    pub control_cost: f64,
    pub treatment_cost: f64,
}

impl StudyReport {
    pub fn time_improvement(&self) -> f64 {
        1.0 - self.treatment_total_min / self.control_total_min
    }
    pub fn cost_improvement(&self) -> f64 {
        1.0 - self.treatment_cost / self.control_cost
    }
}

/// Run one study round: execute the sweep on the platform (treatment
/// machine time = real makespan), model the control as the same batch
/// plus manual per-job launches, then assemble the table.
pub fn run_study(
    acai: &Arc<Acai>,
    project: ProjectId,
    user: UserId,
    input_fileset: &str,
    params: StudyParams,
    commands: &[String],
) -> Result<StudyReport> {
    let n = commands.len();
    // Treatment: real batch through the scheduler (the paper fixes ONE
    // 8-CPU machine for both groups, so machine time is the serial sum;
    // the platform's scheduling still runs for provenance/metadata).
    let t0 = acai.clock.now();
    let specs: Vec<JobSpec> = commands
        .iter()
        .enumerate()
        .map(|(i, command)| JobSpec {
            project,
            user,
            name: format!("study-job-{i}"),
            command: command.clone(),
            input_fileset: input_fileset.to_string(),
            output_fileset: format!("study-out-{i}"),
            resources: ResourceConfig::new(8.0, 8192),
            pool: None,
            data_commit: None,
            priority: crate::engine::Priority::Normal,
            gang: 1,
        })
        .collect();
    let records = acai.engine.run_batch(specs)?;
    let _makespan_min = (acai.clock.now() - t0) / 60.0;
    let serial_machine_min: f64 = records
        .iter()
        .filter_map(|r| r.runtime_secs)
        .sum::<f64>()
        / 60.0;

    // Control: same compute, run serially by hand on one VM with a
    // manual launch gap per job.
    let control_machine_min = serial_machine_min + params.launch_manual_per_job_min * n as f64;

    let rows = vec![
        CategoryRow {
            category: "Code Development",
            control_min: params.code_dev_manual_min,
            treatment_min: params.code_dev_acai_min,
        },
        CategoryRow {
            category: "Resource Deployment",
            control_min: params.deploy_manual_min,
            treatment_min: 0.0,
        },
        CategoryRow {
            category: "Experiment Tracking",
            control_min: params.track_manual_per_job_min * n as f64,
            treatment_min: params.track_acai_per_job_min * n as f64,
        },
        CategoryRow {
            category: "Machine Time",
            control_min: control_machine_min,
            treatment_min: serial_machine_min,
        },
    ];
    let control_total: f64 = rows.iter().map(|r| r.control_min).sum();
    let treatment_total: f64 = rows.iter().map(|r| r.treatment_min).sum();
    // Billing model (calibrated to Tables 5/6): the control pays for the
    // VM across its *whole* session (it is deployed from code-dev through
    // tracking); the treatment pays the managed platform a ~25% premium
    // rate but only for its shorter session — netting a small saving,
    // exactly the paper's 2-11%.
    const PLATFORM_PREMIUM: f64 = 1.25;
    let control_cost = params.vm_rate_per_min * control_total;
    let treatment_cost = params.vm_rate_per_min * PLATFORM_PREMIUM * treatment_total;

    Ok(StudyReport {
        jobs: n,
        rows,
        control_total_min: control_total,
        treatment_total_min: treatment_total,
        control_cost,
        treatment_cost,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn command_grids_match_paper_counts() {
        assert_eq!(round1_commands().len(), 16);
        assert_eq!(round2_commands().len(), 72);
    }

    #[test]
    fn params_reflect_paper_tables() {
        let p1 = round1_params();
        assert!(p1.code_dev_manual_min > p1.code_dev_acai_min);
        assert!(p1.track_manual_per_job_min > p1.track_acai_per_job_min);
        let p2 = round2_params();
        assert!(p2.track_manual_per_job_min / p2.track_acai_per_job_min > 5.0);
    }

    #[test]
    fn all_round_commands_parse() {
        for cmd in round1_commands().iter().chain(round2_commands().iter()) {
            crate::workload::JobCommand::parse(cmd).unwrap();
        }
    }
}
