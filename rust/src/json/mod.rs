//! Minimal JSON codec (serde is not available in the offline vendor set).
//!
//! Implements RFC 8259 parsing and serialization for the platform's needs:
//! artifact manifests, API payloads, metadata documents, and persistence
//! journals.  Object key order is preserved (insertion order) so encoded
//! output is deterministic — the kvstore journal relies on that.

mod parse;
mod value;

pub use parse::parse;
pub use value::{Json, JsonBuilder, JsonObject};

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_trips_scalars() {
        for src in ["null", "true", "false", "0", "-1", "3.5", "\"hi\""] {
            let v = parse(src).unwrap();
            assert_eq!(parse(&v.encode()).unwrap(), v, "{src}");
        }
    }

    #[test]
    fn round_trips_nested() {
        let src = r#"{"a": [1, 2.5, {"b": null}], "c": "x\"y\\z", "d": {}}"#;
        let v = parse(src).unwrap();
        let enc = v.encode();
        assert_eq!(parse(&enc).unwrap(), v);
    }

    #[test]
    fn preserves_key_order() {
        let v = parse(r#"{"z": 1, "a": 2, "m": 3}"#).unwrap();
        let keys: Vec<_> = v.as_object().unwrap().keys().collect();
        assert_eq!(keys, ["z", "a", "m"]);
    }

    #[test]
    fn parses_unicode_escapes() {
        let v = parse(r#""Aé\n\t""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "Aé\n\t");
    }

    #[test]
    fn parses_surrogate_pairs() {
        let v = parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "😀");
    }

    #[test]
    fn rejects_garbage() {
        for src in ["", "{", "[1,", "{\"a\"}", "tru", "01", "1.e", "\"\\x\"", "[1]extra"] {
            assert!(parse(src).is_err(), "{src:?} should fail");
        }
    }

    #[test]
    fn encodes_escapes() {
        let v = Json::Str("a\"b\\c\nd\u{1}".into());
        assert_eq!(parse(&v.encode()).unwrap(), v);
    }

    #[test]
    fn builder_api() {
        let v = Json::obj()
            .field("name", "mnist")
            .field("epochs", 20.0)
            .field("ok", true)
            .field("tags", Json::Arr(vec![Json::from("a"), Json::from("b")]))
            .build();
        assert_eq!(v.get("name").and_then(Json::as_str), Some("mnist"));
        assert_eq!(v.get("epochs").and_then(Json::as_f64), Some(20.0));
        assert_eq!(v.get("ok").and_then(Json::as_bool), Some(true));
        assert_eq!(v.get("tags").and_then(Json::as_array).map(|a| a.len()), Some(2));
    }

    #[test]
    fn number_precision_survives() {
        let v = parse("1234567890.123").unwrap();
        assert!((v.as_f64().unwrap() - 1234567890.123).abs() < 1e-6);
    }

    #[test]
    fn deep_nesting_within_limit() {
        let mut s = String::new();
        for _ in 0..100 {
            s.push('[');
        }
        s.push('1');
        for _ in 0..100 {
            s.push(']');
        }
        assert!(parse(&s).is_ok());
    }

    #[test]
    fn rejects_pathological_nesting() {
        let mut s = String::new();
        for _ in 0..100_000 {
            s.push('[');
        }
        assert!(parse(&s).is_err());
    }
}
