//! JSON value representation and serializer.

use std::fmt::Write as _;

/// An ordered JSON object (insertion order preserved for deterministic
/// encoding; lookups are linear, which is fine at document sizes —
/// metadata documents have tens of keys).
#[derive(Debug, Clone, PartialEq, Default)]
pub struct JsonObject {
    entries: Vec<(String, Json)>,
}

impl JsonObject {
    pub fn new() -> Self {
        Self::default()
    }

    /// Insert or replace a key.
    pub fn set(&mut self, key: impl Into<String>, value: impl Into<Json>) {
        let key = key.into();
        let value = value.into();
        if let Some(slot) = self.entries.iter_mut().find(|(k, _)| *k == key) {
            slot.1 = value;
        } else {
            self.entries.push((key, value));
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        self.entries.iter().find(|(k, _)| k == key).map(|(_, v)| v)
    }

    pub fn remove(&mut self, key: &str) -> Option<Json> {
        let idx = self.entries.iter().position(|(k, _)| k == key)?;
        Some(self.entries.remove(idx).1)
    }

    pub fn contains_key(&self, key: &str) -> bool {
        self.get(key).is_some()
    }

    pub fn keys(&self) -> impl Iterator<Item = &str> {
        self.entries.iter().map(|(k, _)| k.as_str())
    }

    pub fn iter(&self) -> impl Iterator<Item = (&str, &Json)> {
        self.entries.iter().map(|(k, v)| (k.as_str(), v))
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }
}

/// A JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(JsonObject),
}

impl Json {
    // ---- accessors ----

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as u64),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_array(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_object(&self) -> Option<&JsonObject> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    /// Object field lookup (None for non-objects).
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_object()?.get(key)
    }

    /// Array index lookup.
    pub fn at(&self, idx: usize) -> Option<&Json> {
        self.as_array()?.get(idx)
    }

    // ---- builders ----

    /// Start building an object: `Json::obj().field("a", 1.0).build()`.
    pub fn obj() -> JsonBuilder {
        JsonBuilder(JsonObject::new())
    }

    // ---- encoding ----

    /// Serialize to a compact JSON string.
    pub fn encode(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{n}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(items) => {
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(obj) => {
                out.push('{');
                for (i, (k, v)) in obj.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Fluent object builder.
pub struct JsonBuilder(JsonObject);

impl JsonBuilder {
    pub fn field(mut self, key: impl Into<String>, value: impl Into<Json>) -> Self {
        self.0.set(key, value);
        self
    }

    pub fn build(self) -> Json {
        Json::Obj(self.0)
    }
}

impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<u64> for Json {
    fn from(n: u64) -> Self {
        Json::Num(n as f64)
    }
}
impl From<u32> for Json {
    fn from(n: u32) -> Self {
        Json::Num(n as f64)
    }
}
impl From<i64> for Json {
    fn from(n: i64) -> Self {
        Json::Num(n as f64)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl From<JsonObject> for Json {
    fn from(o: JsonObject) -> Self {
        Json::Obj(o)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}
