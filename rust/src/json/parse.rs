//! Recursive-descent JSON parser (RFC 8259).

use super::value::{Json, JsonObject};
use crate::error::{AcaiError, Result};

/// Maximum nesting depth — bounds stack use on hostile input.
const MAX_DEPTH: usize = 512;

/// Parse a JSON document. Trailing non-whitespace is an error.
pub fn parse(src: &str) -> Result<Json> {
    let mut p = Parser {
        bytes: src.as_bytes(),
        pos: 0,
        depth: 0,
    };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != p.bytes.len() {
        return Err(p.err("trailing characters after document"));
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
    depth: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> AcaiError {
        AcaiError::Json(format!("{msg} at byte {}", self.pos))
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let b = self.peek()?;
        self.pos += 1;
        Some(b)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.bump() == Some(b) {
            Ok(())
        } else {
            self.pos = self.pos.saturating_sub(1);
            Err(self.err(&format!("expected {:?}", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.depth += 1;
        if self.depth > MAX_DEPTH {
            return Err(self.err("nesting too deep"));
        }
        let v = match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(b'-' | b'0'..=b'9') => self.number(),
            Some(c) => Err(self.err(&format!("unexpected {:?}", c as char))),
            None => Err(self.err("unexpected end of input")),
        };
        self.depth -= 1;
        v
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {word:?}")))
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut obj = JsonObject::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(obj));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            obj.set(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(obj)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or '}'"));
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => {
                    self.pos = self.pos.saturating_sub(1);
                    return Err(self.err("expected ',' or ']'"));
                }
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(out),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'u') => {
                        let cp = self.hex4()?;
                        // Surrogate pair handling.
                        if (0xD800..0xDC00).contains(&cp) {
                            if self.bump() != Some(b'\\') || self.bump() != Some(b'u') {
                                return Err(self.err("lone high surrogate"));
                            }
                            let low = self.hex4()?;
                            if !(0xDC00..0xE000).contains(&low) {
                                return Err(self.err("invalid low surrogate"));
                            }
                            let c = 0x10000 + ((cp - 0xD800) << 10) + (low - 0xDC00);
                            out.push(
                                char::from_u32(c)
                                    .ok_or_else(|| self.err("bad surrogate pair"))?,
                            );
                        } else if (0xDC00..0xE000).contains(&cp) {
                            return Err(self.err("lone low surrogate"));
                        } else {
                            out.push(
                                char::from_u32(cp).ok_or_else(|| self.err("bad codepoint"))?,
                            );
                        }
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(b) if b < 0x20 => return Err(self.err("raw control char in string")),
                Some(b) => {
                    // Re-decode UTF-8 multibyte sequences from the raw input.
                    if b < 0x80 {
                        out.push(b as char);
                    } else {
                        let start = self.pos - 1;
                        let width = utf8_width(b);
                        let end = start + width;
                        if end > self.bytes.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let s = std::str::from_utf8(&self.bytes[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        out.push_str(s);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32> {
        let mut v = 0u32;
        for _ in 0..4 {
            let b = self.bump().ok_or_else(|| self.err("truncated \\u"))?;
            let d = (b as char).to_digit(16).ok_or_else(|| self.err("bad hex"))?;
            v = v * 16 + d;
        }
        Ok(v)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        // Integer part: "0" or [1-9][0-9]*
        match self.peek() {
            Some(b'0') => {
                self.pos += 1;
                if matches!(self.peek(), Some(b'0'..=b'9')) {
                    return Err(self.err("leading zero"));
                }
            }
            Some(b'1'..=b'9') => {
                while matches!(self.peek(), Some(b'0'..=b'9')) {
                    self.pos += 1;
                }
            }
            _ => return Err(self.err("expected digit")),
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            if !matches!(self.peek(), Some(b'0'..=b'9')) {
                return Err(self.err("expected fraction digit"));
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            if !matches!(self.peek(), Some(b'0'..=b'9')) {
                return Err(self.err("expected exponent digit"));
            }
            while matches!(self.peek(), Some(b'0'..=b'9')) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| self.err(&format!("bad number: {e}")))
    }
}

fn utf8_width(first: u8) -> usize {
    if first & 0xE0 == 0xC0 {
        2
    } else if first & 0xF0 == 0xE0 {
        3
    } else {
        4
    }
}
