//! # ACAI — Accelerated Cloud for Artificial Intelligence
//!
//! A full reproduction of the ACAI platform (Chen et al., CMU 2024): an
//! end-to-end cloud ML platform consisting of a **data lake** (versioned
//! files, file sets, metadata, provenance DAG) and an **execution engine**
//! (per-user FIFO scheduling with quotas, containerized execution, log
//! capture, job profiling, and learned resource auto-provisioning).
//!
//! The crate is organised in six tiers:
//!
//! 1. **Storage substrate** — [`storage`]: the shared machinery under
//!    every store: `ShardedMap` (N lock shards keyed by key hash — point
//!    ops lock one shard, not the store), `Journal` (append-only JSON
//!    log with batched writes and crash-recovery replay), and the
//!    `Table` trait (get/put/delete/scan/read-modify-write) the upper
//!    layers program against.
//! 2. **Cloud-store stand-ins** — from-scratch analogues of the services
//!    the paper runs on, all backed by tier 1: [`objectstore`]
//!    (S3 + SNS), [`kvstore`] (MySQL), [`docstore`] (MongoDB),
//!    [`graphstore`] (Neo4j), plus [`bus`] (Redis pub/sub), [`cluster`]
//!    (Kubernetes — elastic node pools with autoscaling, best-fit
//!    bin-packing placement, and seeded spot preemption), [`httpd`]
//!    (HTTP microservice plumbing), [`json`], [`prng`], [`simclock`].
//! 3. **ACAI services** — the paper's contribution: [`credential`],
//!    [`datalake`], [`engine`], [`pricing`], [`profiler`],
//!    [`autoprovision`], [`workload`], [`sdk`], [`usability`].  The
//!    datalake, the engine's job registry, and the experiment registry
//!    ([`engine::experiment`]) hold `Arc<dyn Table>` handles, never
//!    concrete store internals; per-key read-modify-write preserves the
//!    paper's sequential version assignment (§4.4.3) without cross-key
//!    serialization.  File bodies lower onto a content-addressed,
//!    refcounted chunk store ([`datalake::cas`]) — versions that share
//!    content share storage, and job placement prefers nodes whose
//!    chunk caches already hold the input (cold bytes bill as transfer
//!    time).  Pipelines, workflow replay, and hyperparameter sweeps
//!    share one dependency-DAG scheduling path ([`engine::dag`]) under
//!    the per-user quota.
//! 4. **Runtime bridge** — [`runtime`]: loads the AOT-lowered JAX/Pallas
//!    modules (`artifacts/*.hlo.txt`) via PJRT and executes them from the
//!    hot paths (profiler fit/predict, the MLP job payload); the PJRT
//!    backend is feature-gated (`pjrt`), with an inert offline stub.
//! 5. **Observability tier** — [`obs`]: the typed metrics registry
//!    (counters / gauges / fixed-bucket histograms behind sharded
//!    atomics; one snapshot renders both the `GET /v1/metrics` JSON and
//!    the `?format=prometheus` text exposition) and the span-based
//!    trace store (lock-sharded bounded ring; deterministic span ids
//!    from the platform PRNG stream) that records every job-lifecycle
//!    transition and API request, surfaced as `GET /v1/trace/jobs/{id}`
//!    and `GET /v1/trace/requests/{request_id}`.
//! 6. **API tier** — [`api`]: the versioned `/v1` REST edge — a
//!    path-template router with typed parameters and a middleware chain
//!    (request-id, per-route metrics, token auth), strict DTO codecs
//!    with the uniform error envelope, and an **async job + experiment
//!    lifecycle** (`POST /v1/jobs` and `POST /v1/experiments` → 202,
//!    completion via the background [`engine::EngineDriver`]).  The
//!    [`sdk`] exposes the same surface through the `AcaiApi` trait,
//!    implemented both in-process ([`sdk::Client`]) and over the wire
//!    ([`sdk::RemoteClient`]).
//!
//! See `DESIGN.md` for the substitution table, the `/v1` route table,
//! and the experiment index.

pub mod autoprovision;
pub mod api;
pub mod bus;
pub mod cluster;
pub mod config;
pub mod credential;
pub mod datalake;
pub mod docstore;
pub mod engine;
pub mod error;
pub mod graphstore;
pub mod httpd;
pub mod ids;
pub mod json;
pub mod kvstore;
pub mod objectstore;
pub mod obs;
pub mod platform;
pub mod pricing;
pub mod prng;
pub mod profiler;
pub mod runtime;
pub mod sdk;
pub mod simclock;
pub mod storage;
pub mod testkit;
pub mod usability;
pub mod workload;

pub use error::{AcaiError, Result};
pub use platform::{Acai, PlatformConfig};
