//! # ACAI — Accelerated Cloud for Artificial Intelligence
//!
//! A full reproduction of the ACAI platform (Chen et al., CMU 2024): an
//! end-to-end cloud ML platform consisting of a **data lake** (versioned
//! files, file sets, metadata, provenance DAG) and an **execution engine**
//! (per-user FIFO scheduling with quotas, containerized execution, log
//! capture, job profiling, and learned resource auto-provisioning).
//!
//! The crate is organised in three tiers:
//!
//! 1. **Substrates** — from-scratch stand-ins for the cloud services the
//!    paper runs on: [`objectstore`] (S3 + SNS), [`kvstore`] (MySQL),
//!    [`docstore`] (MongoDB), [`graphstore`] (Neo4j), [`bus`] (Redis
//!    pub/sub), [`cluster`] (Kubernetes), [`httpd`] (HTTP microservice
//!    plumbing), plus [`json`], [`prng`], [`simclock`].
//! 2. **ACAI services** — the paper's contribution: [`credential`],
//!    [`datalake`], [`engine`], [`pricing`], [`profiler`],
//!    [`autoprovision`], [`workload`], [`sdk`], [`usability`].
//! 3. **Runtime bridge** — [`runtime`]: loads the AOT-lowered JAX/Pallas
//!    modules (`artifacts/*.hlo.txt`) via PJRT and executes them from the
//!    hot paths (profiler fit/predict, the MLP job payload).
//!
//! See `DESIGN.md` for the substitution table and the experiment index.

pub mod autoprovision;
pub mod api;
pub mod bus;
pub mod cluster;
pub mod config;
pub mod credential;
pub mod datalake;
pub mod docstore;
pub mod engine;
pub mod error;
pub mod graphstore;
pub mod httpd;
pub mod ids;
pub mod json;
pub mod kvstore;
pub mod objectstore;
pub mod platform;
pub mod pricing;
pub mod prng;
pub mod profiler;
pub mod runtime;
pub mod sdk;
pub mod simclock;
pub mod testkit;
pub mod usability;
pub mod workload;

pub use error::{AcaiError, Result};
pub use platform::{Acai, PlatformConfig};
