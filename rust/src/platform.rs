//! Platform assembly: wire every service into one deployable [`Acai`].

use std::sync::Arc;

use crate::api::tenant::TenantRegistry;
use crate::autoprovision::AutoProvisioner;
use crate::bus::Bus;
use crate::cluster::Cluster;
pub use crate::config::PlatformConfig;
use crate::credential::CredentialServer;
use crate::datalake::DataLake;
use crate::engine::{EngineDriver, ExecutionEngine, ExperimentStore};
use crate::error::Result;
use crate::kvstore::KvStore;
use crate::objectstore::ObjectStore;
use crate::obs::{MetricSample, Obs};
use crate::pricing::PricingModel;
use crate::profiler::Profiler;
use crate::runtime::Runtime;
use crate::simclock::SimClock;
use crate::storage::SharedTable;
use crate::workload::{SimParams, Workloads};

/// One ACAI deployment (paper Figure 6, assembled in-process).
pub struct Acai {
    pub config: PlatformConfig,
    pub clock: SimClock,
    pub bus: Bus,
    pub credentials: CredentialServer,
    pub datalake: DataLake,
    pub cluster: Cluster,
    pub engine: Arc<ExecutionEngine>,
    pub profiler: Profiler,
    pub provisioner: AutoProvisioner,
    /// Experiment registry (hyperparameter sweeps + trial tracking),
    /// persisted on the same storage table tier as the data lake.
    pub experiments: ExperimentStore,
    pub pricing: PricingModel,
    /// Per-project admission control + usage accounting for the REST
    /// edge (rate limits, quotas, the billing counters).
    pub tenants: Arc<TenantRegistry>,
    /// Observability bundle: the typed metrics registry (one source of
    /// truth behind `GET /v1/metrics` and `?format=prometheus`) and the
    /// span-based trace store behind `GET /v1/trace/...`.
    pub obs: Arc<Obs>,
    pub runtime: Option<Arc<Runtime>>,
    objects: ObjectStore,
    /// Background engine driver (async job lifecycle).  Started lazily
    /// by the first [`Acai::driver`] call — unit tests that drive the
    /// engine manually never pay for (or race with) the thread.
    driver: std::sync::OnceLock<EngineDriver>,
}

impl Acai {
    /// Boot a platform from config.  Loads the PJRT runtime if
    /// `artifacts_dir` is set (the heavyweight path: compiles 4 HLO
    /// modules once).
    pub fn boot(config: PlatformConfig) -> Result<Acai> {
        let clock = SimClock::new();
        let bus = Bus::new();
        let kv: SharedTable = Arc::new(match &config.journal {
            Some(path) => KvStore::open_with(
                path,
                crate::storage::DEFAULT_SHARDS,
                config.journal_batch,
            )?,
            None => KvStore::in_memory(),
        });
        let objects = ObjectStore::new(clock.clone(), bus.clone());
        let datalake = DataLake::new(kv.clone(), objects.clone(), bus.clone(), clock.clone());
        let experiments = ExperimentStore::with_table(kv);
        let cluster = Cluster::new(config.cluster.clone(), clock.clone());
        let runtime = match &config.artifacts_dir {
            Some(dir) => Some(Arc::new(Runtime::load(dir)?)),
            None => None,
        };
        let params = SimParams {
            noise: config.noise,
            ..Default::default()
        };
        let workloads = Arc::new(Workloads::new(params, runtime.clone()));
        let pricing = PricingModel::default();
        let obs = Arc::new(Obs::new(config.seed));
        let engine = Arc::new(ExecutionEngine::new(
            cluster.clone(),
            bus.clone(),
            datalake.clone(),
            workloads,
            pricing,
            clock.clone(),
            config.quota_k,
            config.seed,
            config.checkpoint_secs,
            obs.clone(),
        ));
        let profiler = Profiler::new(engine.clone(), runtime.clone(), config.profile_barrier);
        let provisioner = AutoProvisioner::new(pricing);
        let credentials = CredentialServer::new(config.seed);
        let tenants = Arc::new(TenantRegistry::new(config.tenant.clone()));
        register_collectors(&obs, &cluster, &datalake, &tenants, &engine);
        Ok(Acai {
            config,
            clock,
            bus,
            credentials,
            datalake,
            cluster,
            engine,
            profiler,
            provisioner,
            experiments,
            pricing,
            tenants,
            obs,
            runtime,
            objects,
            driver: std::sync::OnceLock::new(),
        })
    }

    /// The background engine driver, starting it on first use.  The API
    /// tier calls this on submit/kill so `POST /v1/jobs` can return 202
    /// immediately and let jobs complete off the request path.
    pub fn driver(&self) -> &EngineDriver {
        self.driver
            .get_or_init(|| EngineDriver::start(self.engine.clone()))
    }

    /// The underlying object store (testing + failure injection).
    pub fn object_store(&self) -> ObjectStore {
        self.objects.clone()
    }

    /// Set a project's fair-share weight (global admin only): persists
    /// it on the project record and mirrors it into the scheduler's
    /// DRF accounting.  Returns the project id.
    pub fn set_project_weight(
        &self,
        root_token: &str,
        name: &str,
        weight: f64,
    ) -> Result<crate::ids::ProjectId> {
        let pid = self
            .credentials
            .set_project_weight(root_token, name, weight)?;
        self.engine.scheduler.set_weight(pid, weight)?;
        Ok(pid)
    }

    /// Boot with default config (no PJRT, no noise) — the test fixture.
    pub fn boot_default() -> Acai {
        Self::boot(PlatformConfig::default()).expect("default boot cannot fail")
    }
}

/// Register the pull-style metric sources: counter blocks that already
/// live in other tiers (cluster, data plane, tenants, fair-share
/// views) surface in every registry snapshot without double
/// bookkeeping.
fn register_collectors(
    obs: &Obs,
    cluster: &Cluster,
    datalake: &DataLake,
    tenants: &Arc<TenantRegistry>,
    engine: &Arc<ExecutionEngine>,
) {
    let c = cluster.clone();
    obs.metrics.register_collector(move || {
        let k = c.counters();
        vec![
            MetricSample::counter("acai_cluster_containers_launched_total", k.launched),
            MetricSample::counter("acai_cluster_containers_completed_total", k.completed),
            MetricSample::counter(
                "acai_cluster_containers_preempted_total",
                k.preempted_containers,
            ),
            MetricSample::counter("acai_cluster_nodes_preempted_total", k.preempted_nodes),
            MetricSample::counter("acai_cluster_scale_up_events_total", k.scale_up_events),
            MetricSample::counter(
                "acai_cluster_scale_down_events_total",
                k.scale_down_events,
            ),
            MetricSample::counter("acai_cluster_nodes_added_total", k.nodes_added),
            MetricSample::counter("acai_cluster_nodes_removed_total", k.nodes_removed),
            MetricSample::counter(
                "acai_cluster_placement_failures_total",
                k.placement_failures,
            ),
            MetricSample::counter("acai_cluster_cache_hit_bytes_total", k.cache_hit_bytes),
            MetricSample::counter(
                "acai_cluster_cold_bytes_transferred_total",
                k.cold_bytes_transferred,
            ),
            MetricSample::counter("acai_cluster_transfer_micros_total", k.transfer_micros),
        ]
    });
    let d = datalake.clone();
    obs.metrics.register_collector(move || {
        let cas = d.cas.stats();
        vec![
            MetricSample::counter("acai_data_logical_bytes_total", cas.logical_bytes),
            MetricSample::counter("acai_data_stored_bytes_total", cas.stored_bytes),
            MetricSample::counter("acai_data_deduped_bytes_total", cas.deduped_bytes),
            MetricSample::counter("acai_data_dedup_hits_total", cas.dedup_hits),
            MetricSample::gauge("acai_data_live_chunks", cas.chunks as f64),
        ]
    });
    let t = tenants.clone();
    obs.metrics.register_collector(move || {
        let mut out = Vec::new();
        for (project, usage) in t.all_usage() {
            let p = project.to_string();
            out.push(
                MetricSample::counter("acai_tenant_requests_total", usage.requests)
                    .with_label("project", &p),
            );
            out.push(
                MetricSample::counter("acai_tenant_throttled_total", usage.throttled)
                    .with_label("project", &p),
            );
            out.push(
                MetricSample::counter("acai_tenant_rejected_total", usage.rejected)
                    .with_label("project", &p),
            );
        }
        out
    });
    let s = engine.scheduler.clone();
    obs.metrics.register_collector(move || {
        s.project_shares()
            .into_iter()
            .map(|share| {
                MetricSample::gauge("acai_scheduler_project_share", share.share)
                    .with_label("project", &share.project.to_string())
            })
            .collect()
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_boot_wires_everything() {
        let acai = Acai::boot_default();
        assert!(acai.runtime.is_none());
        assert_eq!(acai.engine.registry.count(), 0);
        let (nodes, _) = acai.cluster.utilization().1.checked_div(1000).map(|n| (n, ())).unwrap();
        assert!(nodes > 0);
    }

    #[test]
    fn journal_backed_boot() {
        let dir = std::env::temp_dir().join(format!("acai-plat-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let journal = dir.join("journal.log");
        let _ = std::fs::remove_file(&journal);
        let config = PlatformConfig {
            journal: Some(journal.clone()),
            ..Default::default()
        };
        let acai = Acai::boot(config).unwrap();
        acai.datalake
            .storage
            .upload(crate::ids::ProjectId(1), &[("/f", b"x")])
            .unwrap();
        assert!(journal.exists());
        let _ = std::fs::remove_file(&journal);
    }

    /// Group-commit wiring end to end: `journal_batch > 1` buffers
    /// records in the kvstore journal, and [`crate::datalake::DataLake::flush`]
    /// (the barrier `serve_one` and `run_until_idle` run) makes them
    /// durable — a second platform booted from the same journal sees
    /// every barriered write.
    #[test]
    fn batched_journal_survives_reboot_after_flush_barrier() {
        let dir = std::env::temp_dir().join(format!("acai-gc-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let journal = dir.join("batched.log");
        let _ = std::fs::remove_file(&journal);
        let config = PlatformConfig {
            journal: Some(journal.clone()),
            journal_batch: 8,
            ..Default::default()
        };
        let acai = Acai::boot(config).unwrap();
        acai.datalake
            .storage
            .upload(crate::ids::ProjectId(1), &[("/cfg", b"batched-bytes")])
            .unwrap();
        acai.datalake.flush();

        let reboot = Acai::boot(PlatformConfig {
            journal: Some(journal.clone()),
            ..Default::default()
        })
        .unwrap();
        assert_eq!(
            reboot
                .datalake
                .storage
                .read(crate::ids::ProjectId(1), "/cfg", None)
                .unwrap(),
            b"batched-bytes"
        );
        let _ = std::fs::remove_file(&journal);
    }

    /// Acceptance: a warm cache-hit launch moves input bytes without a
    /// single deep copy.  Job 1 warms the inter-job cache; job 2 reads
    /// the same file-set version through [`crate::datalake::DataLake::materialize_cached`]
    /// (an `Arc` clone of shared [`crate::storage::Bytes`] windows), and
    /// its output upload hands owned buffers to the chunk store — the
    /// deep-copy counter stays at zero across the whole second launch.
    #[test]
    fn warm_cache_hit_launch_is_zero_copy() {
        use crate::storage::bytes::copy_counter;
        let acai = Acai::boot_default();
        let p = crate::ids::ProjectId(1);
        // multi-chunk input so the zero-copy claim covers concat too
        let body: Vec<u8> = (0u8..=250).cycle().take(300_000).collect();
        acai.datalake.storage.upload(p, &[("/train", &body)]).unwrap();
        acai.datalake.filesets.create(p, "train", &["/train"], "u").unwrap();
        let spec = |name: &str, out: &str| crate::engine::JobSpec {
            project: p,
            user: crate::ids::UserId(1),
            name: name.into(),
            command: "python train_mnist.py --epoch 1".into(),
            input_fileset: "train".into(),
            output_fileset: out.into(),
            resources: crate::cluster::ResourceConfig::new(1.0, 1024),
            pool: None,
            data_commit: None,
            priority: crate::engine::Priority::Normal,
            gang: 1,
        };
        let j1 = acai.engine.submit(spec("cold", "out-cold")).unwrap();
        acai.engine.run_until_idle();
        assert!(acai.engine.registry.get(j1).unwrap().state.is_terminal());

        copy_counter::reset();
        let j2 = acai.engine.submit(spec("warm", "out-warm")).unwrap();
        acai.engine.run_until_idle();
        assert!(acai.engine.registry.get(j2).unwrap().state.is_terminal());
        assert_eq!(
            copy_counter::get(),
            0,
            "warm launch must not deep-copy input bytes"
        );
    }
}
