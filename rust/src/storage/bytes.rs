//! [`Bytes`]: the zero-copy byte window under the whole data plane.
//!
//! An immutable view into a shared, reference-counted buffer: cloning
//! is an `Arc` bump, [`Bytes::slice`] is pointer arithmetic, and
//! chunking a request body is N windows over ONE allocation instead of
//! N `to_vec()` copies.  Everything that used to move `Arc<Vec<u8>>` /
//! `Vec<u8>` between the object store, the CAS, the download paths and
//! the HTTP response writer now moves `Bytes`.
//!
//! Ownership rules (the "zero-copy data plane" contract, see
//! DESIGN.md):
//!
//! - `Bytes` is immutable — there is no way to write through a window,
//!   so windows over one buffer may be shared freely across threads;
//! - `From<Vec<u8>>` is zero-copy (the vec becomes the backing buffer);
//!   `From<&[u8]>` and [`Bytes::to_vec`] are the *only* deep copies;
//! - [`Bytes::concat`] of windows that are contiguous views of one
//!   buffer returns a wider window of that same buffer — the join half
//!   of split→join is free when the split produced the parts.
//!
//! Under `#[cfg(test)]` a thread-local deep-copy counter records every
//! buffer copy, so tests *assert* zero-copy instead of hoping: see
//! [`copy_counter`].

use std::ops::{Bound, Deref, RangeBounds};
use std::sync::Arc;

/// Thread-local deep-copy accounting (test builds only).  Thread-local
/// rather than global so concurrently running tests cannot perturb each
/// other's counts.
#[cfg(test)]
pub mod copy_counter {
    use std::cell::Cell;

    thread_local! {
        static DEEP_COPIES: Cell<u64> = Cell::new(0);
    }

    /// Record one buffer copy (called by the `Bytes` copy paths).
    pub fn bump() {
        DEEP_COPIES.with(|c| c.set(c.get() + 1));
    }

    /// Deep copies performed by this thread since the last [`reset`].
    pub fn get() -> u64 {
        DEEP_COPIES.with(|c| c.get())
    }

    /// Zero this thread's counter.
    pub fn reset() {
        DEEP_COPIES.with(|c| c.set(0));
    }
}

#[cfg(test)]
fn count_copy() {
    copy_counter::bump();
}

#[cfg(not(test))]
fn count_copy() {}

/// An immutable, cheaply-cloneable window into a shared byte buffer.
#[derive(Clone)]
pub struct Bytes {
    buf: Arc<Vec<u8>>,
    off: usize,
    len: usize,
}

impl Bytes {
    /// The empty window.
    pub fn new() -> Bytes {
        Bytes {
            buf: Arc::new(Vec::new()),
            off: 0,
            len: 0,
        }
    }

    /// Deep-copy a slice into a fresh buffer (the counted copy path —
    /// prefer `From<Vec<u8>>` when the caller owns the allocation).
    pub fn copy_from_slice(bytes: &[u8]) -> Bytes {
        count_copy();
        Bytes::from_vec_uncounted(bytes.to_vec())
    }

    fn from_vec_uncounted(v: Vec<u8>) -> Bytes {
        let len = v.len();
        Bytes {
            buf: Arc::new(v),
            off: 0,
            len,
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// This window's start offset within its backing buffer (windows
    /// produced by chunking one body are contiguous: each starts where
    /// the previous ended).
    pub fn offset(&self) -> usize {
        self.off
    }

    /// Do two windows share one backing buffer?
    pub fn same_buffer(&self, other: &Bytes) -> bool {
        Arc::ptr_eq(&self.buf, &other.buf)
    }

    /// The bytes of this window.
    pub fn as_slice(&self) -> &[u8] {
        &self.buf[self.off..self.off + self.len]
    }

    /// A sub-window: shares the backing buffer, no bytes move.
    /// Panics if the range exceeds this window (same contract as slice
    /// indexing).
    pub fn slice(&self, range: impl RangeBounds<usize>) -> Bytes {
        let lo = match range.start_bound() {
            Bound::Included(&n) => n,
            Bound::Excluded(&n) => n + 1,
            Bound::Unbounded => 0,
        };
        let hi = match range.end_bound() {
            Bound::Included(&n) => n + 1,
            Bound::Excluded(&n) => n,
            Bound::Unbounded => self.len,
        };
        assert!(
            lo <= hi && hi <= self.len,
            "slice {lo}..{hi} out of bounds for Bytes of len {}",
            self.len
        );
        Bytes {
            buf: self.buf.clone(),
            off: self.off + lo,
            len: hi - lo,
        }
    }

    /// Deep-copy the window into an owned `Vec` (counted).
    pub fn to_vec(&self) -> Vec<u8> {
        count_copy();
        self.as_slice().to_vec()
    }

    /// Join windows.  When every part is a view of ONE buffer and the
    /// windows are contiguous (each starts where the previous ends),
    /// the result is a single wider window of that buffer — zero-copy.
    /// Otherwise the parts are copied once into an exactly-sized
    /// buffer (one counted copy regardless of part count).
    pub fn concat(parts: &[Bytes]) -> Bytes {
        match parts {
            [] => Bytes::new(),
            [one] => one.clone(),
            [first, rest @ ..] => {
                let contiguous = rest
                    .iter()
                    .try_fold(first.off + first.len, |end, p| {
                        (p.same_buffer(first) && p.off == end).then_some(end + p.len)
                    })
                    .is_some();
                if contiguous {
                    return Bytes {
                        buf: first.buf.clone(),
                        off: first.off,
                        len: parts.iter().map(|p| p.len).sum(),
                    };
                }
                count_copy();
                let total: usize = parts.iter().map(|p| p.len).sum();
                let mut out = Vec::with_capacity(total);
                for p in parts {
                    out.extend_from_slice(p.as_slice());
                }
                Bytes::from_vec_uncounted(out)
            }
        }
    }
}

impl Default for Bytes {
    fn default() -> Bytes {
        Bytes::new()
    }
}

impl Deref for Bytes {
    type Target = [u8];

    fn deref(&self) -> &[u8] {
        self.as_slice()
    }
}

impl AsRef<[u8]> for Bytes {
    fn as_ref(&self) -> &[u8] {
        self.as_slice()
    }
}

/// Zero-copy: the vec becomes the backing buffer.
impl From<Vec<u8>> for Bytes {
    fn from(v: Vec<u8>) -> Bytes {
        Bytes::from_vec_uncounted(v)
    }
}

/// Deep copy (counted) — the caller only has a borrow.
impl From<&[u8]> for Bytes {
    fn from(b: &[u8]) -> Bytes {
        Bytes::copy_from_slice(b)
    }
}

/// Deep copy (counted) — borrow convenience for literals.
impl<const N: usize> From<&[u8; N]> for Bytes {
    fn from(b: &[u8; N]) -> Bytes {
        Bytes::copy_from_slice(b)
    }
}

/// Deep copy (counted) — borrow convenience.
impl From<&Vec<u8>> for Bytes {
    fn from(v: &Vec<u8>) -> Bytes {
        Bytes::copy_from_slice(v)
    }
}

impl From<String> for Bytes {
    fn from(s: String) -> Bytes {
        Bytes::from(s.into_bytes())
    }
}

impl std::fmt::Debug for Bytes {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Bytes({} bytes)", self.len)
    }
}

impl PartialEq for Bytes {
    fn eq(&self, other: &Bytes) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl Eq for Bytes {}

impl PartialEq<[u8]> for Bytes {
    fn eq(&self, other: &[u8]) -> bool {
        self.as_slice() == other
    }
}

impl PartialEq<&[u8]> for Bytes {
    fn eq(&self, other: &&[u8]) -> bool {
        self.as_slice() == *other
    }
}

impl PartialEq<Vec<u8>> for Bytes {
    fn eq(&self, other: &Vec<u8>) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl PartialEq<Bytes> for Vec<u8> {
    fn eq(&self, other: &Bytes) -> bool {
        self.as_slice() == other.as_slice()
    }
}

impl<const N: usize> PartialEq<[u8; N]> for Bytes {
    fn eq(&self, other: &[u8; N]) -> bool {
        self.as_slice() == other
    }
}

impl<const N: usize> PartialEq<&[u8; N]> for Bytes {
    fn eq(&self, other: &&[u8; N]) -> bool {
        self.as_slice() == *other
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_vec_is_zero_copy_and_slicing_shares_the_buffer() {
        copy_counter::reset();
        let b = Bytes::from((0u8..100).collect::<Vec<u8>>());
        let mid = b.slice(10..60);
        let sub = mid.slice(5..25);
        assert_eq!(mid.len(), 50);
        assert_eq!(sub, b.slice(15..35));
        assert!(sub.same_buffer(&b));
        assert_eq!(sub.offset(), 15);
        assert_eq!(copy_counter::get(), 0, "windowing must not copy");
    }

    #[test]
    fn copy_paths_are_counted() {
        copy_counter::reset();
        let b = Bytes::from(&b"hello"[..]); // borrow: deep copy
        assert_eq!(copy_counter::get(), 1);
        let v = b.to_vec();
        assert_eq!(v, b"hello");
        assert_eq!(copy_counter::get(), 2);
    }

    #[test]
    fn concat_of_contiguous_windows_is_free() {
        copy_counter::reset();
        let b = Bytes::from((0u8..64).collect::<Vec<u8>>());
        let parts: Vec<Bytes> = (0..4).map(|i| b.slice(i * 16..(i + 1) * 16)).collect();
        let joined = Bytes::concat(&parts);
        assert!(joined.same_buffer(&b));
        assert_eq!(joined, b);
        assert_eq!(copy_counter::get(), 0);
        // a ranged join of a contiguous subset is free too
        let ranged = Bytes::concat(&parts[1..3]);
        assert_eq!(ranged, b.slice(16..48));
        assert_eq!(copy_counter::get(), 0);
    }

    #[test]
    fn concat_of_foreign_windows_copies_exactly_once() {
        copy_counter::reset();
        let a = Bytes::from(vec![1, 2, 3]);
        let b = Bytes::from(vec![4, 5]);
        let joined = Bytes::concat(&[a, b]);
        assert_eq!(joined, &[1, 2, 3, 4, 5]);
        assert_eq!(copy_counter::get(), 1);
    }

    #[test]
    fn degenerate_ranges() {
        let b = Bytes::from(vec![9; 10]);
        assert_eq!(b.slice(..).len(), 10);
        assert_eq!(b.slice(10..10).len(), 0);
        assert_eq!(b.slice(0..0).len(), 0);
        assert!(Bytes::new().is_empty());
        assert_eq!(Bytes::concat(&[]), Bytes::new());
    }

    #[test]
    #[should_panic(expected = "out of bounds")]
    fn out_of_range_slice_panics() {
        Bytes::from(vec![0; 4]).slice(2..6);
    }
}
