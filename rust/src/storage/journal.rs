//! [`Journal`]: append-only JSON write-ahead log with crash recovery.
//!
//! Extracted from the kvstore so any substrate can opt into durability.
//! One JSON record per line; replaying the file in order rebuilds the
//! store.  Writes go through a `BufWriter` and are flushed every
//! `batch` appends (default 1 — write-through, so a simulated crash
//! loses nothing; perf-oriented callers raise the batch and call
//! [`Journal::flush`] at their own barriers).

use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use crate::error::{AcaiError, Result};
use crate::json::{parse, Json};

struct Inner {
    writer: BufWriter<File>,
    /// Appends since the last flush.
    pending: usize,
    /// Total appends over the journal's lifetime (perf counter).
    appended: u64,
}

/// An append-only JSON log bound to one file.
pub struct Journal {
    path: PathBuf,
    batch: usize,
    inner: Mutex<Inner>,
}

impl Journal {
    /// Open (creating if absent) with write-through flushing.
    pub fn open(path: impl Into<PathBuf>) -> Result<Journal> {
        Self::open_batched(path, 1)
    }

    /// Open with an explicit flush batch size (clamped to at least 1).
    /// Records buffered past the last flush are lost on a crash —
    /// that's the durability/throughput dial.
    pub fn open_batched(path: impl Into<PathBuf>, batch: usize) -> Result<Journal> {
        let path = path.into();
        let file = std::fs::OpenOptions::new()
            .create(true)
            .append(true)
            .open(&path)?;
        Ok(Journal {
            path,
            batch: batch.max(1),
            inner: Mutex::new(Inner {
                writer: BufWriter::new(file),
                pending: 0,
                appended: 0,
            }),
        })
    }

    /// Replay an existing journal file: parsed records, in append order.
    /// Missing file = empty journal.  Corrupt lines are a hard error
    /// (a torn store must not silently half-load).
    pub fn replay(path: impl AsRef<Path>) -> Result<Vec<Json>> {
        let path = path.as_ref();
        if !path.exists() {
            return Ok(Vec::new());
        }
        let f = File::open(path)?;
        let mut records = Vec::new();
        for (lineno, line) in BufReader::new(f).lines().enumerate() {
            let line = line?;
            if line.trim().is_empty() {
                continue;
            }
            let rec = parse(&line).map_err(|e| {
                AcaiError::Storage(format!("journal {path:?} line {}: {e}", lineno + 1))
            })?;
            records.push(rec);
        }
        Ok(records)
    }

    /// Append one record; flushes when the batch fills.
    pub fn append(&self, record: &Json) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        writeln!(inner.writer, "{}", record.encode())?;
        inner.appended += 1;
        inner.pending += 1;
        if inner.pending >= self.batch {
            inner.writer.flush()?;
            inner.pending = 0;
        }
        Ok(())
    }

    /// Force buffered records to disk.
    pub fn flush(&self) -> Result<()> {
        let mut inner = self.inner.lock().unwrap();
        inner.writer.flush()?;
        inner.pending = 0;
        Ok(())
    }

    /// The journal file path.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Total records appended through this handle.
    pub fn appended(&self) -> u64 {
        self.inner.lock().unwrap().appended
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let dir = std::env::temp_dir().join(format!("acai-journal-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join(name);
        let _ = std::fs::remove_file(&path);
        path
    }

    #[test]
    fn append_then_replay_round_trips() {
        let path = tmp("round-trip.log");
        let j = Journal::open(&path).unwrap();
        j.append(&Json::obj().field("op", "put").field("k", "a").build()).unwrap();
        j.append(&Json::obj().field("op", "del").field("k", "a").build()).unwrap();
        let records = Journal::replay(&path).unwrap();
        assert_eq!(records.len(), 2);
        assert_eq!(records[0].get("op").and_then(Json::as_str), Some("put"));
        assert_eq!(records[1].get("op").and_then(Json::as_str), Some("del"));
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn missing_file_replays_empty() {
        assert!(Journal::replay("/nonexistent/journal.log").unwrap().is_empty());
    }

    #[test]
    fn corrupt_lines_are_rejected_with_line_number() {
        let path = tmp("corrupt.log");
        std::fs::write(&path, "{\"k\":1}\nGARBAGE\n").unwrap();
        let err = Journal::replay(&path).unwrap_err();
        assert!(err.to_string().contains("line 2"), "{err}");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn batched_appends_reach_disk_after_flush() {
        let path = tmp("batched.log");
        let j = Journal::open_batched(&path, 64).unwrap();
        for i in 0..10u64 {
            j.append(&Json::from(i)).unwrap();
        }
        // buffered: the file may be shorter than 10 records until flush
        j.flush().unwrap();
        assert_eq!(Journal::replay(&path).unwrap().len(), 10);
        assert_eq!(j.appended(), 10);
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn write_through_batch_is_durable_per_append() {
        let path = tmp("write-through.log");
        let j = Journal::open(&path).unwrap();
        j.append(&Json::from(1u64)).unwrap();
        // no explicit flush: batch=1 flushed already
        assert_eq!(Journal::replay(&path).unwrap().len(), 1);
        let _ = std::fs::remove_file(&path);
    }
}
