//! The shared storage substrate (the tier under the cloud-store
//! stand-ins).
//!
//! Before this module existed, each of the four substrates —
//! [`crate::kvstore`] (MySQL), [`crate::docstore`] (MongoDB),
//! [`crate::objectstore`] (S3), [`crate::graphstore`] (Neo4j) — was an
//! independent `Arc<Mutex<Inner>>`: one global lock per store, private
//! journal code, private map plumbing.  Under concurrent pipelines
//! (the paper's §4.4 scalability story) every operation serialized on
//! those four locks.
//!
//! This module factors out the common machinery:
//!
//! - [`ShardedMap`] — N lock shards keyed by key hash (default
//!   [`shard::DEFAULT_SHARDS`] = 16); point ops lock one shard, ordered
//!   scans merge per-shard runs;
//! - [`Journal`] — append-only JSON log with batched/buffered writes,
//!   explicit [`Journal::flush`], and crash-recovery [`Journal::replay`];
//! - [`Table`] — the get/put/delete/scan/read-modify-write interface all
//!   four substrates implement, which the data lake and the engine's job
//!   registry program against ([`SharedTable`] = `Arc<dyn Table>`);
//! - [`Bytes`] — the immutable shared byte window the whole data plane
//!   moves bodies as (clone = `Arc` bump, slice = pointer math).
//!
//! The paper's correctness anchor — sequential version-number assignment
//! under the "server-side lock" — is preserved per key:
//! [`Table::read_modify_write`] bumps each version counter atomically
//! under its own shard lock, eliminating the cross-key serialization
//! without giving up the guarantee.

pub mod bytes;
pub mod journal;
pub mod shard;
pub mod table;

pub use bytes::Bytes;
pub use journal::Journal;
pub use shard::{ShardedMap, DEFAULT_SHARDS};
pub use table::{
    bump_version, claim_version, ns_key, ns_range, ns_split, publish_version, Rmw, SharedTable,
    Table,
};
