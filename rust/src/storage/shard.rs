//! [`ShardedMap`]: the lock-sharded ordered map under every substrate.
//!
//! N independent `Mutex<BTreeMap>` shards, keyed by key hash.  Point
//! operations (get / insert / remove / per-key read-modify-write) lock
//! exactly one shard, so operations on different keys proceed in
//! parallel — this replaces the single global `Mutex<Inner>` the four
//! cloud-store stand-ins used to serialize on.  Ordered scans visit every
//! shard (each shard is itself ordered) and merge the per-shard runs.
//!
//! Locking discipline: a closure passed to [`ShardedMap::locked`] /
//! [`ShardedMap::read_modify_write`] runs while holding that key's shard
//! lock.  It must not call back into the same map (same-shard re-entry
//! self-deadlocks) nor into another store's locked section (cross-store
//! lock-order inversions).  Upper layers follow the rule "compute under
//! one key's lock, compose across keys outside it".

use std::collections::BTreeMap;
use std::hash::{Hash, Hasher};
use std::ops::RangeBounds;
use std::sync::Mutex;

/// Default shard count — enough to make 8-way contention rare while
/// keeping scan fan-in cheap.
pub const DEFAULT_SHARDS: usize = 16;

/// A concurrent ordered map with per-shard locking.
pub struct ShardedMap<K, V> {
    shards: Box<[Mutex<BTreeMap<K, V>>]>,
}

impl<K: Ord + Hash, V> Default for ShardedMap<K, V> {
    fn default() -> Self {
        Self::new(DEFAULT_SHARDS)
    }
}

impl<K: Ord + Hash, V> ShardedMap<K, V> {
    /// A map with `shards` lock shards (clamped to at least 1).
    pub fn new(shards: usize) -> Self {
        let n = shards.max(1);
        let shards = (0..n).map(|_| Mutex::new(BTreeMap::new())).collect();
        Self { shards }
    }

    /// Number of lock shards.
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    fn shard(&self, key: &K) -> &Mutex<BTreeMap<K, V>> {
        let mut h = std::collections::hash_map::DefaultHasher::new();
        key.hash(&mut h);
        &self.shards[(h.finish() as usize) % self.shards.len()]
    }

    /// Run `f` on the shard owning `key`, holding its lock.  The one
    /// escape hatch for multi-step operations that must be atomic with
    /// respect to that key (see the module docs for what `f` must not
    /// do).
    pub fn locked<T>(&self, key: &K, f: impl FnOnce(&mut BTreeMap<K, V>) -> T) -> T {
        let mut shard = self.shard(key).lock().unwrap();
        f(&mut shard)
    }

    /// Insert or replace; returns the previous value.
    pub fn insert(&self, key: K, value: V) -> Option<V> {
        let mut shard = self.shard(&key).lock().unwrap();
        shard.insert(key, value)
    }

    /// Remove; returns the previous value.
    pub fn remove(&self, key: &K) -> Option<V> {
        self.shard(key).lock().unwrap().remove(key)
    }

    pub fn contains_key(&self, key: &K) -> bool {
        self.shard(key).lock().unwrap().contains_key(key)
    }

    /// Total entries across all shards.
    pub fn len(&self) -> usize {
        self.shards.iter().map(|s| s.lock().unwrap().len()).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.shards.iter().all(|s| s.lock().unwrap().is_empty())
    }

    /// Remove every entry.
    pub fn clear(&self) {
        for s in &self.shards {
            s.lock().unwrap().clear();
        }
    }
}

impl<K: Ord + Hash + Clone, V: Clone> ShardedMap<K, V> {
    /// Clone of the value at `key`.
    pub fn get(&self, key: &K) -> Option<V> {
        self.shard(key).lock().unwrap().get(key).cloned()
    }

    /// Atomic per-key read-modify-write: `f` sees the current value and
    /// returns the replacement (`None` deletes).  Holds only the owning
    /// shard's lock — the primitive behind sequential version assignment.
    pub fn read_modify_write(
        &self,
        key: &K,
        f: impl FnOnce(Option<&V>) -> Option<V>,
    ) -> Option<V> {
        let mut shard = self.shard(key).lock().unwrap();
        match f(shard.get(key)) {
            Some(v) => {
                shard.insert(key.clone(), v.clone());
                Some(v)
            }
            None => {
                shard.remove(key);
                None
            }
        }
    }

    /// Key-ordered entries within `range`, merged across shards.  Each
    /// shard is locked once (in turn, never two at a time).
    pub fn range<R: RangeBounds<K> + Clone>(&self, range: R) -> Vec<(K, V)> {
        let mut out: Vec<(K, V)> = Vec::new();
        for s in &self.shards {
            let shard = s.lock().unwrap();
            out.extend(shard.range(range.clone()).map(|(k, v)| (k.clone(), v.clone())));
        }
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }

    /// All entries, key-ordered.
    pub fn snapshot(&self) -> Vec<(K, V)> {
        self.range(..)
    }

    /// Number of entries within `range`, without cloning keys or values.
    pub fn count_range<R: RangeBounds<K> + Clone>(&self, range: R) -> usize {
        self.shards
            .iter()
            .map(|s| s.lock().unwrap().range(range.clone()).count())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    #[test]
    fn point_ops_round_trip() {
        let m: ShardedMap<String, u64> = ShardedMap::default();
        assert!(m.insert("a".into(), 1).is_none());
        assert_eq!(m.insert("a".into(), 2), Some(1));
        assert_eq!(m.get(&"a".into()), Some(2));
        assert!(m.contains_key(&"a".into()));
        assert_eq!(m.remove(&"a".into()), Some(2));
        assert!(m.is_empty());
    }

    #[test]
    fn scans_are_key_ordered_across_shards() {
        let m: ShardedMap<String, u64> = ShardedMap::new(4);
        for (i, k) in ["d", "a", "c", "b", "e"].iter().enumerate() {
            m.insert(k.to_string(), i as u64);
        }
        let keys: Vec<String> = m.snapshot().into_iter().map(|(k, _)| k).collect();
        assert_eq!(keys, ["a", "b", "c", "d", "e"]);
        let mid: Vec<String> = m
            .range("b".to_string().."d".to_string())
            .into_iter()
            .map(|(k, _)| k)
            .collect();
        assert_eq!(mid, ["b", "c"]);
        assert_eq!(m.count_range("b".to_string().."d".to_string()), 2);
    }

    #[test]
    fn one_shard_degenerates_to_a_plain_map() {
        let m: ShardedMap<u64, u64> = ShardedMap::new(1);
        for i in 0..100 {
            m.insert(i, i * 2);
        }
        assert_eq!(m.len(), 100);
        assert_eq!(m.shard_count(), 1);
    }

    #[test]
    fn rmw_is_atomic_under_contention() {
        let m: Arc<ShardedMap<String, u64>> = Arc::new(ShardedMap::default());
        let mut handles = vec![];
        for _ in 0..8 {
            let m = m.clone();
            handles.push(std::thread::spawn(move || {
                for _ in 0..1000 {
                    m.read_modify_write(&"ctr".to_string(), |cur| {
                        Some(cur.copied().unwrap_or(0) + 1)
                    });
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(m.get(&"ctr".to_string()), Some(8000));
    }

    #[test]
    fn rmw_none_deletes() {
        let m: ShardedMap<String, u64> = ShardedMap::default();
        m.insert("k".into(), 7);
        assert!(m.read_modify_write(&"k".to_string(), |_| None).is_none());
        assert!(m.get(&"k".to_string()).is_none());
    }

    #[test]
    fn tuple_keys_support_table_scoped_ranges() {
        let m: ShardedMap<(String, String), u64> = ShardedMap::default();
        m.insert(("t1".into(), "a".into()), 1);
        m.insert(("t1".into(), "b".into()), 2);
        m.insert(("t2".into(), "a".into()), 3);
        let lo = ("t1".to_string(), String::new());
        let hi = ("t1\u{0}".to_string(), String::new());
        let hits = m.range(lo..hi);
        assert_eq!(hits.len(), 2);
        assert!(hits.iter().all(|((t, _), _)| t == "t1"));
    }
}
