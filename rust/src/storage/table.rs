//! [`Table`]: the uniform row-store interface over every substrate.
//!
//! Upper layers (the data lake, the job registry) program against this
//! trait instead of concrete store internals, so any substrate — the
//! embedded kvstore, the document store, the object store, even the
//! graph store's node properties — can back them.  Rows are [`Json`]
//! values in named tables with string primary keys.
//!
//! The load-bearing operation is [`Table::read_modify_write`]: an atomic
//! per-key update executed under that key's shard lock.  It is how the
//! paper's "server-side lock" guarantee (sequential version-number
//! assignment, §4.4.3) survives the sharded refactor: instead of
//! serializing every writer behind one store-wide mutex, each version
//! counter is bumped atomically under its own key's lock.
//!
//! Rules for `read_modify_write` closures (enforced by convention, see
//! [`crate::storage::shard`] for why): no calls back into any store, no
//! I/O other than the store's own journal, and no panics — compute the
//! next row from the current one, nothing else.  The closure runs at
//! most once per call and must be side-effect-free on the error path.

use std::sync::Arc;

use crate::error::Result;
use crate::json::Json;

/// Outcome of a read-modify-write closure.
#[derive(Debug, Clone)]
pub enum Rmw {
    /// Replace (or create) the row.
    Put(Json),
    /// Delete the row.
    Delete,
    /// Leave the row untouched.
    Keep,
}

/// A named-table row store with per-key atomic updates.
pub trait Table: Send + Sync {
    /// Fetch a row.
    fn get(&self, table: &str, key: &str) -> Option<Json>;

    /// Insert or replace a row.
    fn put(&self, table: &str, key: &str, value: Json) -> Result<()>;

    /// Delete a row; `true` if it existed.
    fn delete(&self, table: &str, key: &str) -> Result<bool>;

    /// All (key, row) pairs of a table, key-ordered.
    fn scan(&self, table: &str) -> Vec<(String, Json)>;

    /// (key, row) pairs with keys starting with `prefix`, key-ordered.
    fn scan_prefix(&self, table: &str, prefix: &str) -> Vec<(String, Json)>;

    /// (key, row) pairs with keys in `[lo, hi)`, key-ordered.
    fn scan_range(&self, table: &str, lo: &str, hi: &str) -> Vec<(String, Json)>;

    /// Row count of a table.
    fn count(&self, table: &str) -> usize {
        self.scan(table).len()
    }

    /// Atomic per-key read-modify-write.  `f` observes the current row
    /// (if any) and decides the outcome; errors abort with no write.
    /// Returns the row *after* the operation (`None` once deleted or
    /// when `Keep` left an absent row absent).
    fn read_modify_write(
        &self,
        table: &str,
        key: &str,
        f: &mut dyn FnMut(Option<&Json>) -> Result<Rmw>,
    ) -> Result<Option<Json>>;

    /// Flush any buffered durability machinery (no-op for in-memory
    /// stores).
    fn flush(&self) -> Result<()> {
        Ok(())
    }
}

/// Shared handle the upper layers hold.
pub type SharedTable = Arc<dyn Table>;

/// Namespace separator for stores whose native keyspace is flat (the
/// object store's object keys, the graph store's property rows): table
/// and row key are joined as `table␟key`.
pub const NS_SEP: char = '\u{1f}';

/// One past [`NS_SEP`] — `table` + this char is the exclusive upper
/// bound of the table's namespace in a flat ordered keyspace.
pub const NS_END: char = '\u{20}';

/// Join a table name and row key into a namespaced flat key.
pub fn ns_key(table: &str, key: &str) -> String {
    format!("{table}{NS_SEP}{key}")
}

/// Half-open flat-key range covering `table`'s rows whose keys start at
/// `prefix` (pass `""` for the whole table).  Prefix scans must still
/// filter with `starts_with` — the range is bounded by the namespace
/// end, not the prefix end.
pub fn ns_range(table: &str, prefix: &str) -> (String, String) {
    (ns_key(table, prefix), format!("{table}{NS_END}"))
}

/// Row key of a namespaced flat key (None for keys outside any
/// namespace).
pub fn ns_split(flat: &str) -> Option<&str> {
    flat.split_once(NS_SEP).map(|(_, key)| key)
}

fn version_of(row: Option<&Json>) -> u32 {
    row.and_then(|v| v.get("version"))
        .and_then(Json::as_u64)
        .unwrap_or(0) as u32
}

/// Fetch-and-increment a `{"version": n}` counter row, returning the
/// newly assigned version (1 for a fresh row).  The common idiom behind
/// sequential version assignment — factored here so every call site
/// bumps identically.
pub fn bump_version(table: &dyn Table, table_name: &str, key: &str) -> Result<u32> {
    let row = table.read_modify_write(table_name, key, &mut |cur| {
        Ok(Rmw::Put(
            Json::obj().field("version", version_of(cur) as u64 + 1).build(),
        ))
    })?;
    Ok(version_of(row.as_ref()).max(1))
}

/// Claim the next version for `key` *without publishing it*: bumps a
/// private sequence row in `seq_table`, floored by the already-published
/// pointer in `latest_table` (so stores whose journals predate the
/// sequence row never re-issue a live version).  Pair with
/// [`publish_version`] after the versioned row itself is written — the
/// published pointer then never references a row that does not exist
/// yet, which the old whole-store transaction used to guarantee.
pub fn claim_version(
    table: &dyn Table,
    seq_table: &str,
    latest_table: &str,
    key: &str,
) -> Result<u32> {
    let floor = version_of(table.get(latest_table, key).as_ref());
    let row = table.read_modify_write(seq_table, key, &mut |cur| {
        let next = version_of(cur).max(floor) + 1;
        Ok(Rmw::Put(Json::obj().field("version", next as u64).build()))
    })?;
    Ok(version_of(row.as_ref()).max(1))
}

/// Publish `version` as the latest for `key`, monotonically: a stale
/// publisher (whose claim lost the race) never moves the pointer
/// backwards.
pub fn publish_version(
    table: &dyn Table,
    latest_table: &str,
    key: &str,
    version: u32,
) -> Result<()> {
    table.read_modify_write(latest_table, key, &mut |cur| {
        if version > version_of(cur) {
            Ok(Rmw::Put(Json::obj().field("version", version as u64).build()))
        } else {
            Ok(Rmw::Keep)
        }
    })?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvstore::KvStore;

    #[test]
    fn trait_is_object_safe_and_shared() {
        let table: SharedTable = Arc::new(KvStore::in_memory());
        table.put("t", "a", Json::from(1u64)).unwrap();
        assert_eq!(table.get("t", "a").unwrap().as_u64(), Some(1));
        assert_eq!(table.count("t"), 1);
        assert!(table.delete("t", "a").unwrap());
        assert!(table.get("t", "a").is_none());
    }

    #[test]
    fn bump_version_is_dense_from_one() {
        let kv = KvStore::in_memory();
        assert_eq!(bump_version(&kv, "latest", "k").unwrap(), 1);
        assert_eq!(bump_version(&kv, "latest", "k").unwrap(), 2);
        assert_eq!(bump_version(&kv, "latest", "other").unwrap(), 1);
    }

    #[test]
    fn claim_then_publish_never_dangles() {
        let kv = KvStore::in_memory();
        // claim does not move the published pointer
        assert_eq!(claim_version(&kv, "seq", "latest", "k").unwrap(), 1);
        assert!(kv.get("latest", "k").is_none());
        publish_version(&kv, "latest", "k", 1).unwrap();
        assert_eq!(claim_version(&kv, "seq", "latest", "k").unwrap(), 2);
        // stale publisher cannot move the pointer backwards
        publish_version(&kv, "latest", "k", 2).unwrap();
        publish_version(&kv, "latest", "k", 1).unwrap();
        let latest = kv.get("latest", "k").unwrap();
        assert_eq!(latest.get("version").and_then(Json::as_u64), Some(2));
    }

    #[test]
    fn claim_is_floored_by_published_pointer() {
        // a journal that predates the sequence row: latest=5, no seq
        let kv = KvStore::in_memory();
        kv.put("latest", "k", Json::obj().field("version", 5u64).build())
            .unwrap();
        assert_eq!(claim_version(&kv, "seq", "latest", "k").unwrap(), 6);
    }

    #[test]
    fn rmw_keep_writes_nothing() {
        let kv = KvStore::in_memory();
        kv.put("t", "k", Json::from(5u64)).unwrap();
        let writes_before = kv.write_count();
        let after = kv
            .read_modify_write("t", "k", &mut |_| Ok(Rmw::Keep))
            .unwrap();
        assert_eq!(after.unwrap().as_u64(), Some(5));
        assert_eq!(kv.write_count(), writes_before);
    }

    #[test]
    fn rmw_error_aborts_without_write() {
        let kv = KvStore::in_memory();
        kv.put("t", "k", Json::from(5u64)).unwrap();
        let err = kv.read_modify_write("t", "k", &mut |_| {
            Err(crate::error::AcaiError::conflict("nope"))
        });
        assert!(err.is_err());
        assert_eq!(kv.get("t", "k").unwrap().as_u64(), Some(5));
    }
}
