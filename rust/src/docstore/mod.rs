//! Document store with secondary indexes — the MongoDB analogue (§4.5.1).
//!
//! Collections hold JSON documents keyed by an artifact id.  Every
//! top-level key is indexed automatically on first sight (the paper:
//! "the metadata server will create an index for a key if it does not
//! exist ... boosts query performance but increases storage cost"), so
//! equality, range, and max/min queries run off BTree indexes instead of
//! collection scans.
//!
//! Collections live in a [`crate::storage::ShardedMap`] keyed by
//! collection name: operations on different collections (per-project
//! metadata, per-kind artifact sets) lock different shards and proceed
//! in parallel; within one collection, document + index mutations stay
//! atomic under that collection's shard lock.
//!
//! Query surface (what the paper's metadata retrieval needs, §3.2.3):
//! equality match on key-value pairs, numeric/string range queries (e.g.
//! `create_time` today), and max/min queries (e.g. highest `precision`),
//! combinable with AND semantics.

use std::collections::{BTreeMap, HashMap, HashSet};
use std::sync::Arc;

/// Documents are shared refcounted values: queries return `Arc<Json>`
/// clones (a refcount bump), not deep copies — the metadata range-query
/// hot path materializes thousands of documents per call.
pub type Doc = Arc<Json>;

/// The reserved per-document version counter maintained by
/// [`DocStore::update_guarded`] — the optimistic-concurrency guard
/// (vss `global_version` semantics).  User tags may not shadow it.
pub const VERSION_FIELD: &str = "version";

use crate::error::{AcaiError, Result};
use crate::json::Json;
use crate::storage::{Rmw, ShardedMap, Table};

/// An orderable projection of a JSON scalar, usable as a BTree key.
#[derive(Debug, Clone, PartialEq)]
pub enum IndexKey {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
}

impl Eq for IndexKey {}

impl Ord for IndexKey {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        use IndexKey::*;
        fn rank(k: &IndexKey) -> u8 {
            match k {
                Null => 0,
                Bool(_) => 1,
                Num(_) => 2,
                Str(_) => 3,
            }
        }
        match (self, other) {
            (Bool(a), Bool(b)) => a.cmp(b),
            (Num(a), Num(b)) => a.total_cmp(b),
            (Str(a), Str(b)) => a.cmp(b),
            (a, b) => rank(a).cmp(&rank(b)),
        }
    }
}

impl PartialOrd for IndexKey {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl IndexKey {
    /// Index projection of a JSON value; arrays/objects are not indexable.
    pub fn of(v: &Json) -> Option<IndexKey> {
        match v {
            Json::Null => Some(IndexKey::Null),
            Json::Bool(b) => Some(IndexKey::Bool(*b)),
            Json::Num(n) => Some(IndexKey::Num(*n)),
            Json::Str(s) => Some(IndexKey::Str(s.clone())),
            _ => None,
        }
    }
}

/// One query clause.
#[derive(Debug, Clone)]
pub enum Clause {
    /// `key == value`.
    Eq(String, Json),
    /// `lo <= key <= hi` (either bound optional).
    Range {
        key: String,
        lo: Option<IndexKey>,
        hi: Option<IndexKey>,
    },
    /// Document(s) with the maximum value of `key`.
    Max(String),
    /// Document(s) with the minimum value of `key`.
    Min(String),
}

impl Clause {
    /// Convenience: numeric greater-or-equal.
    pub fn gte(key: impl Into<String>, v: f64) -> Clause {
        Clause::Range {
            key: key.into(),
            lo: Some(IndexKey::Num(v)),
            hi: None,
        }
    }
    /// Convenience: numeric less-or-equal.
    pub fn lte(key: impl Into<String>, v: f64) -> Clause {
        Clause::Range {
            key: key.into(),
            lo: None,
            hi: Some(IndexKey::Num(v)),
        }
    }
    /// Convenience: equality.
    pub fn eq(key: impl Into<String>, v: impl Into<Json>) -> Clause {
        Clause::Eq(key.into(), v.into())
    }
}

#[derive(Default)]
struct Collection {
    docs: HashMap<String, Doc>,
    /// key -> (index value -> doc ids)
    indexes: HashMap<String, BTreeMap<IndexKey, HashSet<String>>>,
}

impl Collection {
    fn index_doc(&mut self, id: &str, doc: &Json) {
        if let Some(obj) = doc.as_object() {
            for (k, v) in obj.iter() {
                if let Some(ik) = IndexKey::of(v) {
                    self.indexes
                        .entry(k.to_string())
                        .or_default()
                        .entry(ik)
                        .or_default()
                        .insert(id.to_string());
                }
            }
        }
    }

    fn unindex_doc(&mut self, id: &str, doc: &Json) {
        if let Some(obj) = doc.as_object() {
            for (k, v) in obj.iter() {
                if let Some(ik) = IndexKey::of(v) {
                    if let Some(idx) = self.indexes.get_mut(k) {
                        if let Some(set) = idx.get_mut(&ik) {
                            set.remove(id);
                            if set.is_empty() {
                                idx.remove(&ik);
                            }
                        }
                    }
                }
            }
        }
    }

    /// Replace (or create) a doc, keeping indexes coherent.
    fn put_doc(&mut self, id: &str, doc: Json) {
        if let Some(old) = self.docs.remove(id) {
            self.unindex_doc(id, &old);
        }
        self.index_doc(id, &doc);
        self.docs.insert(id.to_string(), Arc::new(doc));
    }

    /// Remove a doc, keeping indexes coherent; true if it existed.
    fn remove_doc(&mut self, id: &str) -> bool {
        match self.docs.remove(id) {
            Some(doc) => {
                self.unindex_doc(id, &doc);
                true
            }
            None => false,
        }
    }

    fn ids_matching(&self, clause: &Clause) -> Option<HashSet<String>> {
        match clause {
            Clause::Eq(key, v) => {
                let ik = IndexKey::of(v)?;
                Some(
                    self.indexes
                        .get(key)
                        .and_then(|idx| idx.get(&ik))
                        .cloned()
                        .unwrap_or_default(),
                )
            }
            Clause::Range { key, lo, hi } => {
                let idx = match self.indexes.get(key) {
                    Some(i) => i,
                    None => return Some(HashSet::new()),
                };
                // BTree range seek — O(log n + hits), not a full index
                // scan (perf_datalake's range-query hot path).
                use std::ops::Bound;
                let lo_bound = match lo {
                    Some(lo) => Bound::Included(lo.clone()),
                    None => Bound::Unbounded,
                };
                let hi_bound = match hi {
                    Some(hi) => Bound::Included(hi.clone()),
                    None => Bound::Unbounded,
                };
                let mut out = HashSet::new();
                for (_, ids) in idx.range((lo_bound, hi_bound)) {
                    out.extend(ids.iter().cloned());
                }
                Some(out)
            }
            Clause::Max(key) => Some(
                self.indexes
                    .get(key)
                    .and_then(|idx| idx.iter().next_back())
                    .map(|(_, ids)| ids.clone())
                    .unwrap_or_default(),
            ),
            Clause::Min(key) => Some(
                self.indexes
                    .get(key)
                    .and_then(|idx| idx.iter().next())
                    .map(|(_, ids)| ids.clone())
                    .unwrap_or_default(),
            ),
        }
    }
}

/// Merge Range clauses sharing a key: intersect their bounds.
fn coalesce_ranges(clauses: &[Clause]) -> Vec<Clause> {
    let mut out: Vec<Clause> = Vec::with_capacity(clauses.len());
    for clause in clauses {
        if let Clause::Range { key, lo, hi } = clause {
            if let Some(Clause::Range {
                lo: plo, hi: phi, ..
            }) = out.iter_mut().find(
                |c| matches!(c, Clause::Range { key: pk, .. } if pk == key),
            ) {
                if let Some(lo) = lo {
                    if plo.as_ref().map_or(true, |p| lo > p) {
                        *plo = Some(lo.clone());
                    }
                }
                if let Some(hi) = hi {
                    if phi.as_ref().map_or(true, |p| hi < p) {
                        *phi = Some(hi.clone());
                    }
                }
                continue;
            }
        }
        out.push(clause.clone());
    }
    out
}

/// The document store handle (one per platform; collections per project).
#[derive(Clone, Default)]
pub struct DocStore {
    collections: Arc<ShardedMap<String, Collection>>,
}

impl DocStore {
    pub fn new() -> Self {
        Self::default()
    }

    /// Run `f` with the collection's shard locked (read view).
    fn read<T>(&self, collection: &str, f: impl FnOnce(Option<&Collection>) -> T) -> T {
        self.collections
            .locked(&collection.to_string(), |shard| f(shard.get(collection)))
    }

    /// Run `f` with the collection's shard locked, creating the
    /// collection on first use.
    fn write<T>(&self, collection: &str, f: impl FnOnce(&mut Collection) -> T) -> T {
        self.collections.locked(&collection.to_string(), |shard| {
            f(shard.entry(collection.to_string()).or_default())
        })
    }

    /// Insert or fully replace a document.
    pub fn put(&self, collection: &str, id: &str, doc: Json) {
        self.write(collection, |coll| coll.put_doc(id, doc));
    }

    /// Merge key-value pairs into an existing document (upsert).
    pub fn update(&self, collection: &str, id: &str, fields: &[(String, Json)]) {
        self.write(collection, |coll| {
            let doc = coll
                .docs
                .remove(id)
                .unwrap_or_else(|| Arc::new(Json::obj().build()));
            coll.unindex_doc(id, &doc);
            // copy-on-write: only updates pay a deep clone
            let mut doc = (*doc).clone();
            if let Json::Obj(obj) = &mut doc {
                for (k, v) in fields {
                    obj.set(k.clone(), v.clone());
                }
            }
            coll.index_doc(id, &doc);
            coll.docs.insert(id.to_string(), Arc::new(doc));
        });
    }

    /// Merge key-value pairs into an existing document, guarded by an
    /// optimistic version check (vss `global_version` semantics).  The
    /// whole read-check-merge runs under the collection's shard lock:
    ///
    /// - `expected = Some(v)` — write only if the document's current
    ///   [`VERSION_FIELD`] equals `v` (a document without one counts
    ///   as version 0); a mismatch is a [`AcaiError::Conflict`] (409)
    ///   and nothing is written;
    /// - `expected = None` — unconditional merge (the legacy
    ///   [`DocStore::update`] behavior).
    ///
    /// Every successful write bumps [`VERSION_FIELD`]; the new version
    /// is returned so callers can chain guarded writes.
    pub fn update_guarded(
        &self,
        collection: &str,
        id: &str,
        fields: &[(String, Json)],
        expected: Option<u64>,
    ) -> Result<u64> {
        self.write(collection, |coll| {
            let current = coll
                .docs
                .get(id)
                .and_then(|doc| doc.get(VERSION_FIELD))
                .and_then(Json::as_u64)
                .unwrap_or(0);
            if let Some(want) = expected {
                if want != current {
                    return Err(AcaiError::conflict(format!(
                        "{id}: expected version {want}, current is {current}"
                    )));
                }
            }
            let doc = coll
                .docs
                .remove(id)
                .unwrap_or_else(|| Arc::new(Json::obj().build()));
            coll.unindex_doc(id, &doc);
            // copy-on-write: only updates pay a deep clone
            let mut doc = (*doc).clone();
            let next = current + 1;
            if let Json::Obj(obj) = &mut doc {
                for (k, v) in fields {
                    obj.set(k.clone(), v.clone());
                }
                obj.set(VERSION_FIELD.to_string(), Json::from(next));
            }
            coll.index_doc(id, &doc);
            coll.docs.insert(id.to_string(), Arc::new(doc));
            Ok(next)
        })
    }

    /// Fetch by id.
    pub fn get(&self, collection: &str, id: &str) -> Option<Doc> {
        self.read(collection, |coll| coll.and_then(|c| c.docs.get(id).cloned()))
    }

    /// Delete by id.
    pub fn delete(&self, collection: &str, id: &str) -> bool {
        self.read_write_existing(collection, |coll| coll.remove_doc(id))
            .unwrap_or(false)
    }

    /// Like [`Self::write`] but only when the collection exists.
    fn read_write_existing<T>(
        &self,
        collection: &str,
        f: impl FnOnce(&mut Collection) -> T,
    ) -> Option<T> {
        self.collections
            .locked(&collection.to_string(), |shard| shard.get_mut(collection).map(f))
    }

    /// AND-combined query. Returns (id, doc) pairs, id-sorted.
    pub fn find(&self, collection: &str, clauses: &[Clause]) -> Result<Vec<(String, Doc)>> {
        // Query planning: coalesce multiple Range clauses on the same key
        // into one (a `gte(k, a) AND lte(k, b)` pair becomes a single
        // index range seek instead of two full id-set builds + an
        // intersection — the metadata range-query hot path).
        let clauses = coalesce_ranges(clauses);
        self.read(collection, |coll| {
            let Some(coll) = coll else {
                return Ok(vec![]);
            };
            let mut ids: Option<HashSet<String>> = None;
            for clause in clauses.iter() {
                let matched = coll.ids_matching(clause).ok_or_else(|| {
                    AcaiError::invalid(format!("unindexable value in clause {clause:?}"))
                })?;
                ids = Some(match ids {
                    None => matched,
                    Some(prev) => prev.intersection(&matched).cloned().collect(),
                });
            }
            let ids = match ids {
                Some(ids) => ids,
                None => coll.docs.keys().cloned().collect(), // no clauses: all
            };
            let mut out: Vec<(String, Doc)> = ids
                .into_iter()
                .filter_map(|id| coll.docs.get(&id).map(|d| (id, d.clone())))
                .collect();
            out.sort_by(|a, b| a.0.cmp(&b.0));
            Ok(out)
        })
    }

    /// Number of documents in a collection.
    pub fn count(&self, collection: &str) -> usize {
        self.read(collection, |coll| coll.map(|c| c.docs.len()).unwrap_or(0))
    }

    /// Id-sorted (id, deep-cloned doc) pairs whose ids satisfy `keep` —
    /// the filter runs before the clone, so narrow scans don't pay for
    /// the whole collection.
    fn scan_matching(
        &self,
        collection: &str,
        keep: impl Fn(&str) -> bool,
    ) -> Vec<(String, Json)> {
        self.read(collection, |coll| {
            let Some(coll) = coll else { return vec![] };
            let mut out: Vec<(String, Json)> = coll
                .docs
                .iter()
                .filter(|(id, _)| keep(id))
                .map(|(id, d)| (id.clone(), (**d).clone()))
                .collect();
            out.sort_by(|a, b| a.0.cmp(&b.0));
            out
        })
    }

    /// Indexed key names of a collection (paper: index-per-key cost).
    pub fn indexed_keys(&self, collection: &str) -> Vec<String> {
        self.read(collection, |coll| {
            coll.map(|c| {
                let mut keys: Vec<_> = c.indexes.keys().cloned().collect();
                keys.sort();
                keys
            })
            .unwrap_or_default()
        })
    }
}

/// [`Table`] view: tables are collections, rows are documents.  Index
/// maintenance rides along on every write, so rows stored through this
/// interface stay queryable via [`DocStore::find`].
impl Table for DocStore {
    fn get(&self, table: &str, key: &str) -> Option<Json> {
        DocStore::get(self, table, key).map(|d| (*d).clone())
    }

    fn put(&self, table: &str, key: &str, value: Json) -> Result<()> {
        DocStore::put(self, table, key, value);
        Ok(())
    }

    fn delete(&self, table: &str, key: &str) -> Result<bool> {
        Ok(DocStore::delete(self, table, key))
    }

    fn scan(&self, table: &str) -> Vec<(String, Json)> {
        self.scan_matching(table, |_| true)
    }

    fn scan_prefix(&self, table: &str, prefix: &str) -> Vec<(String, Json)> {
        self.scan_matching(table, |id| id.starts_with(prefix))
    }

    fn scan_range(&self, table: &str, lo: &str, hi: &str) -> Vec<(String, Json)> {
        self.scan_matching(table, |id| id >= lo && id < hi)
    }

    fn count(&self, table: &str) -> usize {
        DocStore::count(self, table)
    }

    fn read_modify_write(
        &self,
        table: &str,
        key: &str,
        f: &mut dyn FnMut(Option<&Json>) -> Result<Rmw>,
    ) -> Result<Option<Json>> {
        self.write(table, |coll| {
            let cur = coll.docs.get(key).cloned();
            match f(cur.as_deref())? {
                Rmw::Put(v) => {
                    coll.put_doc(key, v.clone());
                    Ok(Some(v))
                }
                Rmw::Delete => {
                    coll.remove_doc(key);
                    Ok(None)
                }
                Rmw::Keep => Ok(cur.map(|d| (*d).clone())),
            }
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn seeded() -> DocStore {
        let ds = DocStore::new();
        ds.put(
            "jobs",
            "job-1",
            Json::obj()
                .field("creator", "john")
                .field("create_time", 100.0)
                .field("model", "BERT")
                .field("precision", 0.7)
                .build(),
        );
        ds.put(
            "jobs",
            "job-2",
            Json::obj()
                .field("creator", "john")
                .field("create_time", 200.0)
                .field("model", "GPT")
                .field("precision", 0.4)
                .build(),
        );
        ds.put(
            "jobs",
            "job-3",
            Json::obj()
                .field("creator", "mary")
                .field("create_time", 300.0)
                .field("model", "BERT")
                .field("precision", 0.9)
                .build(),
        );
        ds
    }

    #[test]
    fn equality_query() {
        let ds = seeded();
        let hits = ds.find("jobs", &[Clause::eq("creator", "john")]).unwrap();
        assert_eq!(hits.len(), 2);
    }

    #[test]
    fn paper_example_query() {
        // "file sets generated by John today using BERT with precision > 0.5"
        let ds = seeded();
        let hits = ds
            .find(
                "jobs",
                &[
                    Clause::eq("creator", "john"),
                    Clause::eq("model", "BERT"),
                    Clause::gte("precision", 0.5),
                    Clause::gte("create_time", 50.0),
                    Clause::lte("create_time", 150.0),
                ],
            )
            .unwrap();
        assert_eq!(hits.len(), 1);
        assert_eq!(hits[0].0, "job-1");
    }

    #[test]
    fn range_query_is_inclusive() {
        let ds = seeded();
        let hits = ds
            .find(
                "jobs",
                &[
                    Clause::gte("create_time", 100.0),
                    Clause::lte("create_time", 200.0),
                ],
            )
            .unwrap();
        assert_eq!(hits.len(), 2);
    }

    #[test]
    fn max_min_queries() {
        let ds = seeded();
        let max = ds.find("jobs", &[Clause::Max("precision".into())]).unwrap();
        assert_eq!(max[0].0, "job-3");
        let min = ds.find("jobs", &[Clause::Min("precision".into())]).unwrap();
        assert_eq!(min[0].0, "job-2");
    }

    #[test]
    fn update_moves_index_entries() {
        let ds = seeded();
        ds.update("jobs", "job-2", &[("precision".into(), Json::from(0.95))]);
        let max = ds.find("jobs", &[Clause::Max("precision".into())]).unwrap();
        assert_eq!(max[0].0, "job-2");
        // old index entry must be gone
        let low = ds.find("jobs", &[Clause::eq("precision", 0.4)]).unwrap();
        assert!(low.is_empty());
    }

    #[test]
    fn guarded_update_enforces_expected_version() {
        let ds = seeded();
        // unguarded write on a versionless doc assigns version 1
        let v = ds
            .update_guarded("jobs", "job-1", &[("precision".into(), Json::from(0.5))], None)
            .unwrap();
        assert_eq!(v, 1);
        // matching guard writes and bumps
        let v = ds
            .update_guarded(
                "jobs",
                "job-1",
                &[("precision".into(), Json::from(0.6))],
                Some(1),
            )
            .unwrap();
        assert_eq!(v, 2);
        // stale guard conflicts and writes nothing
        let err = ds
            .update_guarded(
                "jobs",
                "job-1",
                &[("precision".into(), Json::from(0.0))],
                Some(1),
            )
            .unwrap_err();
        assert_eq!(err.status(), 409);
        let doc = ds.get("jobs", "job-1").unwrap();
        assert_eq!(doc.get("precision").and_then(Json::as_f64), Some(0.6));
        assert_eq!(doc.get(VERSION_FIELD).and_then(Json::as_u64), Some(2));
        // a guard on a fresh doc: expected 0 creates it at version 1
        let v = ds
            .update_guarded("jobs", "job-9", &[("model".into(), Json::from("m"))], Some(0))
            .unwrap();
        assert_eq!(v, 1);
    }

    #[test]
    fn delete_removes_from_indexes() {
        let ds = seeded();
        assert!(ds.delete("jobs", "job-3"));
        let hits = ds.find("jobs", &[Clause::eq("creator", "mary")]).unwrap();
        assert!(hits.is_empty());
        assert_eq!(ds.count("jobs"), 2);
    }

    #[test]
    fn empty_clause_list_returns_all() {
        let ds = seeded();
        assert_eq!(ds.find("jobs", &[]).unwrap().len(), 3);
    }

    #[test]
    fn missing_key_range_matches_nothing() {
        let ds = seeded();
        assert!(ds.find("jobs", &[Clause::gte("nonexistent", 0.0)]).unwrap().is_empty());
    }

    #[test]
    fn indexes_are_created_per_key_automatically() {
        let ds = seeded();
        let keys = ds.indexed_keys("jobs");
        assert!(keys.contains(&"creator".to_string()));
        assert!(keys.contains(&"precision".to_string()));
    }

    #[test]
    fn string_range_queries_work() {
        let ds = seeded();
        let hits = ds
            .find(
                "jobs",
                &[Clause::Range {
                    key: "model".into(),
                    lo: Some(IndexKey::Str("A".into())),
                    hi: Some(IndexKey::Str("C".into())),
                }],
            )
            .unwrap();
        assert_eq!(hits.len(), 2); // the two BERTs
    }

    #[test]
    fn mixed_type_index_keys_do_not_collide() {
        let ds = DocStore::new();
        ds.put("c", "a", Json::obj().field("v", 1.0).build());
        ds.put("c", "b", Json::obj().field("v", "1").build());
        assert_eq!(ds.find("c", &[Clause::eq("v", 1.0)]).unwrap().len(), 1);
        assert_eq!(ds.find("c", &[Clause::eq("v", "1")]).unwrap().len(), 1);
    }

    #[test]
    fn table_rows_are_queryable_documents() {
        let ds = DocStore::new();
        let table: &dyn Table = &ds;
        table
            .put("jobs", "job-9", Json::obj().field("model", "MLP").build())
            .unwrap();
        // the Table write maintained the secondary index
        let hits = ds.find("jobs", &[Clause::eq("model", "MLP")]).unwrap();
        assert_eq!(hits.len(), 1);
        // and rmw keeps it coherent
        table
            .read_modify_write("jobs", "job-9", &mut |cur| {
                let mut doc = cur.cloned().unwrap();
                if let Json::Obj(obj) = &mut doc {
                    obj.set("model", Json::from("XGB"));
                }
                Ok(Rmw::Put(doc))
            })
            .unwrap();
        assert!(ds.find("jobs", &[Clause::eq("model", "MLP")]).unwrap().is_empty());
        assert_eq!(ds.find("jobs", &[Clause::eq("model", "XGB")]).unwrap().len(), 1);
    }
}
