//! REST edge: the credential-server routing of paper §4.1 as a
//! reusable [`Handler`] (used by `acai serve` and the HTTP integration
//! tests).  Every request authenticates `x-acai-token` and is redirected
//! to the matching internal service (Figure 7).

use std::sync::Arc;

use crate::cluster::ResourceConfig;
use crate::datalake::metadata::ArtifactKind;
use crate::httpd::{Handler, Request, Response};
use crate::json::Json;
use crate::platform::Acai;
use crate::sdk::{Client, JobRequest};

/// Build the REST routing table (exposed for the httpd integration test).
pub fn make_handler(acai: Arc<Acai>) -> Handler {
    Arc::new(move |req: &Request| route(&acai, req).unwrap_or_else(|e| Response::error(&e)))
}

fn route(acai: &Arc<Acai>, req: &Request) -> crate::error::Result<Response> {
    use crate::error::AcaiError;
    let path = req.path.as_str();

    // Unauthenticated: project bootstrap (global admin token in body).
    if req.method == "POST" && path == "/projects" {
        let body = req.json()?;
        let root = body.get("root_token").and_then(Json::as_str).unwrap_or("");
        let name = body.get("name").and_then(Json::as_str).unwrap_or("");
        let admin = body.get("admin").and_then(Json::as_str).unwrap_or("admin");
        let (pid, token) = acai.credentials.create_project(root, name, admin)?;
        return Ok(Response::json(
            &Json::obj()
                .field("project", pid.to_string())
                .field("admin_token", token)
                .build(),
        ));
    }

    // Everything else: authenticate, then redirect to the service.
    let token = req
        .header("x-acai-token")
        .ok_or_else(|| AcaiError::Unauthorized("missing x-acai-token".into()))?;
    let client = Client::connect(acai.clone(), token)?;

    match (req.method.as_str(), path) {
        ("POST", "/users") => {
            let body = req.json()?;
            let name = body.get("name").and_then(Json::as_str).unwrap_or("");
            let new_token = acai.credentials.create_user(token, name)?;
            Ok(Response::json(&Json::obj().field("token", new_token).build()))
        }
        ("GET", "/files") => {
            let listing = client.list_files("/");
            let files: Vec<Json> = listing
                .into_iter()
                .map(|(p, v)| Json::obj().field("path", p).field("version", v).build())
                .collect();
            Ok(Response::json(&Json::Arr(files)))
        }
        ("POST", "/filesets") => {
            let body = req.json()?;
            let name = body.get("name").and_then(Json::as_str).unwrap_or("");
            let specs: Vec<String> = body
                .get("specs")
                .and_then(Json::as_array)
                .unwrap_or(&[])
                .iter()
                .filter_map(|s| s.as_str().map(String::from))
                .collect();
            let refs: Vec<&str> = specs.iter().map(|s| s.as_str()).collect();
            let version = client.create_file_set(name, &refs)?;
            Ok(Response::json(&Json::obj().field("version", version).build()))
        }
        ("POST", "/jobs") => {
            let body = req.json()?;
            let get = |k: &str| body.get(k).and_then(Json::as_str).unwrap_or("").to_string();
            let job = client.submit(JobRequest {
                name: get("name"),
                command: get("command"),
                input_fileset: get("input_fileset"),
                output_fileset: get("output_fileset"),
                resources: ResourceConfig::new(
                    body.get("vcpus").and_then(Json::as_f64).unwrap_or(1.0),
                    body.get("mem_mb").and_then(Json::as_u64).unwrap_or(1024) as u32,
                ),
            })?;
            client.wait_all();
            let record = client.job(job)?;
            Ok(Response::json(
                &Json::obj()
                    .field("job", job.to_string())
                    .field("state", record.state.as_str())
                    .field("runtime_secs", record.runtime_secs.unwrap_or(0.0))
                    .field("cost", record.cost.unwrap_or(0.0))
                    .build(),
            ))
        }
        ("GET", "/provenance") => {
            let (nodes, edges) = client.provenance_graph();
            let edges: Vec<Json> = edges
                .into_iter()
                .map(|e| {
                    Json::obj()
                        .field("from", e.from)
                        .field("to", e.to)
                        .field("action", e.action)
                        .field("kind", e.kind)
                        .build()
                })
                .collect();
            Ok(Response::json(
                &Json::obj()
                    .field("nodes", Json::Arr(nodes.into_iter().map(Json::from).collect()))
                    .field("edges", Json::Arr(edges))
                    .build(),
            ))
        }
        ("GET", "/jobs") => {
            let records = acai
                .engine
                .registry
                .list(client.identity().project, None);
            let jobs: Vec<Json> = records
                .into_iter()
                .map(|r| {
                    Json::obj()
                        .field("job", r.id.to_string())
                        .field("name", r.spec.name)
                        .field("state", r.state.as_str())
                        .build()
                })
                .collect();
            Ok(Response::json(&Json::Arr(jobs)))
        }
        ("GET", "/metadata") => {
            // /metadata?kind=jobs&id=job-1
            let mut kind = ArtifactKind::Job;
            let mut id = String::new();
            for pair in req.query.split('&') {
                match pair.split_once('=') {
                    Some(("kind", "files")) => kind = ArtifactKind::File,
                    Some(("kind", "filesets")) => kind = ArtifactKind::FileSet,
                    Some(("kind", _)) => kind = ArtifactKind::Job,
                    Some(("id", v)) => id = v.to_string(),
                    _ => {}
                }
            }
            let doc = acai
                .datalake
                .metadata
                .get(client.identity().project, kind, &id)
                .ok_or_else(|| AcaiError::not_found(id))?;
            Ok(Response::json(&doc))
        }
        _ => Err(AcaiError::not_found(format!(
            "{} {path}",
            req.method
        ))),
    }
}
