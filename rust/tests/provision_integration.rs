//! Profiler + auto-provisioner integration (paper §4.2.2–§4.2.4, §5.1):
//! profile the MNIST template through the real engine, fit, predict,
//! optimize both objectives, and verify the decisions beat the baseline
//! when actually run.

use acai::autoprovision::Objective;
use acai::cluster::ResourceConfig;
use acai::engine::{JobSpec, JobState};
use acai::ids::{ProjectId, UserId};
use acai::{Acai, PlatformConfig};

const P: ProjectId = ProjectId(1);
const U: UserId = UserId(1);

fn platform(noise: f64) -> Acai {
    let config = PlatformConfig {
        noise,
        ..Default::default()
    };
    let acai = Acai::boot(config).unwrap();
    acai.datalake
        .storage
        .upload(P, &[("/data/train.bin", b"data")])
        .unwrap();
    acai.datalake
        .filesets
        .create(P, "mnist", &["/data/train.bin"], "alice")
        .unwrap();
    acai
}

const TEMPLATE: &str = "python train_mnist.py --epoch {1,2,3} --batch-size 256 --learning-rate 0.3";

#[test]
fn profiling_runs_27_trials_and_fits() {
    let acai = platform(0.0);
    let before = acai.engine.registry.count();
    let id = acai
        .profiler
        .profile("mnist", TEMPLATE, P, U, "mnist")
        .unwrap();
    // |cpus| * |mems| * |epoch opts| = 3*3*3 = 27 trials (paper §5.1.1)
    assert_eq!(acai.engine.registry.count() - before, 27);
    let fitted = acai.profiler.get(id).unwrap();
    // the 95% barrier may fit with 26 of 27 (the last trial still runs)
    assert!(fitted.trials.len() >= 26, "{}", fitted.trials.len());
    assert!(fitted.stragglers <= 1);

    // noise-free: the fit must recover the simulator's law
    // t = t1 * e * c^-0.95 * (m/1024)^-0.03
    let theta = fitted.theta;
    assert!((theta[1] + 0.95).abs() < 0.02, "cpu exp {}", theta[1]);
    assert!((theta[2] + 0.03).abs() < 0.02, "mem exp {}", theta[2]);
    assert!((theta[3] - 1.0).abs() < 0.02, "epoch exp {}", theta[3]);
}

#[test]
fn predictions_extrapolate_to_unseen_configs() {
    let acai = platform(0.0);
    acai.profiler.profile("mnist", TEMPLATE, P, U, "mnist").unwrap();
    let fitted = acai.profiler.by_name("mnist").unwrap();

    // predict a 20-epoch run at the paper's baseline (2 vCPU, 7.5 GB):
    // the profiler never saw epoch=20 nor 7.5 GB
    let predicted = fitted.predict(&[20.0, 256.0], ResourceConfig::new(2.0, 7680));
    // ground truth from the simulator: ~64.6 s
    assert!(
        (predicted - 64.6).abs() / 64.6 < 0.05,
        "predicted {predicted}, want ~64.6"
    );
}

#[test]
fn optimize_runtime_fixed_cost_beats_baseline() {
    // The Table 2 experiment: cost cap = baseline cost, minimize runtime.
    let acai = platform(0.0);
    acai.profiler.profile("mnist", TEMPLATE, P, U, "mnist").unwrap();
    let fitted = acai.profiler.by_name("mnist").unwrap();

    let baseline_res = ResourceConfig::new(2.0, 7680);
    let baseline_t = fitted.predict(&[20.0, 256.0], baseline_res);
    let baseline_cost = acai.pricing.cost(baseline_res, baseline_t);

    let decision = acai
        .provisioner
        .optimize(
            &acai.profiler,
            &fitted,
            &[20.0, 256.0],
            Objective::MinRuntime {
                max_cost: baseline_cost,
            },
        )
        .unwrap();
    assert!(decision.predicted_cost <= baseline_cost * 1.0001);
    let speedup = baseline_t / decision.predicted_runtime;
    assert!(speedup > 1.7, "speedup {speedup:.2} (paper claims 1.7x+)");
    // the paper's optimizer picks many more vCPUs than the baseline
    assert!(decision.config.vcpus > baseline_res.vcpus);

    // ...and when actually run, the decision holds up
    let run = |res: ResourceConfig| -> f64 {
        let id = acai
            .engine
            .submit(JobSpec {
                project: P,
                user: U,
                name: "verify".into(),
                command: "python train_mnist.py --epoch 20 --batch-size 256 --learning-rate 0.3"
                    .into(),
                input_fileset: "mnist".into(),
                output_fileset: "verify-out".into(),
                resources: res,
                pool: None,
                data_commit: None,
                priority: acai::engine::Priority::Normal,
                gang: 1,
            })
            .unwrap();
        acai.engine.run_until_idle();
        acai.engine.registry.get(id).unwrap().runtime_secs.unwrap()
    };
    let t_base = run(baseline_res);
    let t_auto = run(decision.config);
    assert!(
        t_base / t_auto > 1.7,
        "measured speedup {:.2}",
        t_base / t_auto
    );
}

#[test]
fn optimize_cost_fixed_runtime_saves_30_percent() {
    // The Table 3 experiment: runtime cap = baseline runtime, min cost.
    let acai = platform(0.0);
    acai.profiler.profile("mnist", TEMPLATE, P, U, "mnist").unwrap();
    let fitted = acai.profiler.by_name("mnist").unwrap();

    let baseline_res = ResourceConfig::new(2.0, 7680);
    let baseline_t = fitted.predict(&[20.0, 256.0], baseline_res);
    let baseline_cost = acai.pricing.cost(baseline_res, baseline_t);

    let decision = acai
        .provisioner
        .optimize(
            &acai.profiler,
            &fitted,
            &[20.0, 256.0],
            Objective::MinCost {
                max_runtime: baseline_t,
            },
        )
        .unwrap();
    assert!(decision.predicted_runtime <= baseline_t * 1.0001);
    let savings = 1.0 - decision.predicted_cost / baseline_cost;
    assert!(savings > 0.30, "savings {savings:.2} (paper claims ~35-39%)");
    // paper Table 3: the optimizer goes to (near-)minimum memory — the
    // sim's tiny memory exponent makes 512 vs 768 MB a near tie
    assert!(decision.config.mem_mb <= 1024, "{:?}", decision.config);
    // with a little more CPU than the baseline to compensate
    assert!(decision.config.vcpus >= baseline_res.vcpus);
}

#[test]
fn infeasible_constraints_error_cleanly() {
    let acai = platform(0.0);
    acai.profiler.profile("mnist", TEMPLATE, P, U, "mnist").unwrap();
    let fitted = acai.profiler.by_name("mnist").unwrap();
    let err = acai
        .provisioner
        .optimize(
            &acai.profiler,
            &fitted,
            &[20.0, 256.0],
            Objective::MinRuntime { max_cost: 1e-9 },
        )
        .unwrap_err();
    assert_eq!(err.status(), 422);
    let err = acai
        .provisioner
        .optimize(
            &acai.profiler,
            &fitted,
            &[20.0, 256.0],
            Objective::MinCost { max_runtime: 0.001 },
        )
        .unwrap_err();
    assert_eq!(err.status(), 422);
}

#[test]
fn decision_grid_classifies_feasibility_like_fig16() {
    let acai = platform(0.0);
    acai.profiler.profile("mnist", TEMPLATE, P, U, "mnist").unwrap();
    let fitted = acai.profiler.by_name("mnist").unwrap();
    let baseline_cost = acai
        .pricing
        .cost(ResourceConfig::new(2.0, 7680), 64.6);
    let decision = acai
        .provisioner
        .optimize(
            &acai.profiler,
            &fitted,
            &[20.0, 256.0],
            Objective::MinRuntime {
                max_cost: baseline_cost,
            },
        )
        .unwrap();
    assert_eq!(decision.grid.len(), 496);
    let feasible = decision.grid.iter().filter(|p| p.feasible).count();
    let infeasible = decision.grid.len() - feasible;
    // Fig 16 shows both red (over budget) and viable regions
    assert!(feasible > 50, "feasible {feasible}");
    assert!(infeasible > 50, "infeasible {infeasible}");
    // every feasible point respects the constraint
    for p in decision.grid.iter().filter(|p| p.feasible) {
        assert!(p.predicted_cost <= baseline_cost * 1.0001);
    }
}

#[test]
fn profiling_under_noise_still_fits_usably() {
    let acai = platform(0.04);
    acai.profiler.profile("mnist", TEMPLATE, P, U, "mnist").unwrap();
    let fitted = acai.profiler.by_name("mnist").unwrap();
    // exponents are close-ish to the law despite noise
    assert!((fitted.theta[3] - 1.0).abs() < 0.3, "{:?}", fitted.theta);
    let predicted = fitted.predict(&[20.0, 256.0], ResourceConfig::new(2.0, 7680));
    assert!((predicted - 64.6).abs() / 64.6 < 0.35, "{predicted}");
}

#[test]
fn jobs_submitted_by_profiler_appear_in_history() {
    let acai = platform(0.0);
    acai.profiler.profile("mnist", TEMPLATE, P, U, "mnist").unwrap();
    let records = acai.engine.registry.list(P, Some(U));
    assert_eq!(records.len(), 27);
    assert!(records.iter().all(|r| r.state == JobState::Finished));
    assert!(records.iter().all(|r| r.spec.name == "profile-mnist"));
}

#[test]
fn distributed_template_fits_two_hinted_args() {
    // §7.2 (future work, implemented): runtime prediction conditioned on
    // the number of nodes — a two-hint template exercises the FEATURES=8
    // multi-argument fit path.
    let acai = platform(0.0);
    acai.profiler
        .profile(
            "spark",
            "python spark_train.py --epoch {1,2,4} --nodes {1,2,4}",
            P,
            U,
            "mnist",
        )
        .unwrap();
    let fitted = acai.profiler.by_name("spark").unwrap();
    // 3 cpus * 3 mems * 3 epochs * 3 nodes = 81 trials
    assert!(fitted.trials.len() >= 77, "{}", fitted.trials.len());
    // recovered exponents: epoch ~ +1.0 (feature 3), nodes ~ -0.8 (feature 4)
    assert!((fitted.theta[3] - 1.0).abs() < 0.03, "{:?}", fitted.theta);
    assert!((fitted.theta[4] + 0.8).abs() < 0.03, "{:?}", fitted.theta);

    // prediction at an unseen corner: 10 epochs on 8 nodes, 4 vCPU each
    let predicted = fitted.predict(&[10.0, 8.0], ResourceConfig::new(4.0, 2048));
    let truth = 4.0 * 6.63 * 10.0 * 8f64.powf(-0.8) * 4f64.powf(-0.95) * 2f64.powf(-0.03);
    assert!(
        (predicted - truth).abs() / truth < 0.05,
        "predicted {predicted}, truth {truth}"
    );

    // and the auto-provisioner optimizes per-worker resources for it
    let decision = acai
        .provisioner
        .optimize(
            &acai.profiler,
            &fitted,
            &[10.0, 8.0],
            Objective::MinCost { max_runtime: 60.0 },
        )
        .unwrap();
    assert!(decision.predicted_runtime <= 60.0);
}
