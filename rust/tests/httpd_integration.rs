//! REST edge over real sockets: the credential-server authenticate +
//! redirect flow of paper §4.1/Figure 7 driven by an HTTP client.

use std::sync::Arc;

use acai::api::make_handler;
use acai::httpd::{get_json, post_json, request, Server};
use acai::json::Json;
use acai::Acai;

fn serve() -> (Arc<Acai>, Server, String) {
    let acai = Arc::new(Acai::boot_default());
    let root = acai.credentials.root_token().to_string();
    let server = Server::serve(0, make_handler(acai.clone())).unwrap();
    (acai, server, root)
}

#[test]
fn bootstrap_project_then_full_flow_over_http() {
    let (_acai, server, root) = serve();
    let addr = server.addr();

    // 1. create a project (global admin)
    let resp = post_json(
        addr,
        "/projects",
        "",
        &Json::obj()
            .field("root_token", root.as_str())
            .field("name", "nlp")
            .field("admin", "alice")
            .build(),
    )
    .unwrap();
    let token = resp.get("admin_token").and_then(Json::as_str).unwrap().to_string();

    // 2. create a second user (project admin privilege)
    let resp = post_json(
        addr,
        "/users",
        &token,
        &Json::obj().field("name", "bob").build(),
    )
    .unwrap();
    assert!(resp.get("token").and_then(Json::as_str).is_some());

    // 3. build a file set (requires data; upload through the data path
    //    is presigned/direct — here we preload via a spec-less set error
    //    first, then a real one after a job runs)
    //    Submit a job with no input instead:
    let resp = post_json(
        addr,
        "/jobs",
        &token,
        &Json::obj()
            .field("name", "http-train")
            .field("command", "python train_mnist.py --epoch 2")
            .field("input_fileset", "")
            .field("output_fileset", "http-model")
            .field("vcpus", 1.0)
            .field("mem_mb", 1024u64)
            .build(),
    )
    .unwrap();
    assert_eq!(resp.get("state").and_then(Json::as_str), Some("finished"));
    assert!(resp.get("runtime_secs").and_then(Json::as_f64).unwrap() > 0.0);

    // 4. job listing + metadata over HTTP
    let jobs = get_json(addr, "/jobs", &token).unwrap();
    assert_eq!(jobs.as_array().unwrap().len(), 1);
    let job_id = jobs.at(0).unwrap().get("job").unwrap().as_str().unwrap().to_string();
    let meta = get_json(addr, &format!("/metadata?kind=jobs&id={job_id}"), &token).unwrap();
    assert_eq!(meta.get("state").and_then(Json::as_str), Some("finished"));

    // 5. provenance graph over HTTP
    let graph = get_json(addr, "/provenance", &token).unwrap();
    let nodes = graph.get("nodes").and_then(Json::as_array).unwrap();
    assert!(nodes.iter().any(|n| n.as_str() == Some("http-model:1")));
}

#[test]
fn requests_without_token_are_401() {
    let (_acai, server, _root) = serve();
    let resp = request(server.addr(), "GET", "/jobs", &[], b"").unwrap();
    assert_eq!(resp.status, 401);
}

#[test]
fn requests_with_bad_token_are_401() {
    let (_acai, server, _root) = serve();
    let resp = request(
        server.addr(),
        "GET",
        "/jobs",
        &[("x-acai-token", "forged")],
        b"",
    )
    .unwrap();
    assert_eq!(resp.status, 401);
}

#[test]
fn project_creation_with_wrong_root_is_403() {
    let (_acai, server, _root) = serve();
    let err = post_json(
        server.addr(),
        "/projects",
        "",
        &Json::obj()
            .field("root_token", "wrong")
            .field("name", "x")
            .field("admin", "a")
            .build(),
    )
    .unwrap_err();
    assert!(err.to_string().contains("403"), "{err}");
}

#[test]
fn unknown_route_is_404() {
    let (acai, server, root) = serve();
    let (_p, token) = acai.credentials.create_project(&root, "p", "u").unwrap();
    let resp = request(
        server.addr(),
        "GET",
        "/nope",
        &[("x-acai-token", token.as_str())],
        b"",
    )
    .unwrap();
    assert_eq!(resp.status, 404);
}

#[test]
fn concurrent_clients_are_isolated_by_token() {
    let (acai, server, root) = serve();
    let addr = server.addr();
    let (_p1, t1) = acai.credentials.create_project(&root, "a", "u").unwrap();
    let (_p2, t2) = acai.credentials.create_project(&root, "b", "u").unwrap();
    let h1 = std::thread::spawn(move || {
        post_json(
            addr,
            "/jobs",
            &t1,
            &Json::obj()
                .field("name", "j1")
                .field("command", "python train_mnist.py --epoch 1")
                .field("input_fileset", "")
                .field("output_fileset", "m1")
                .field("vcpus", 0.5)
                .field("mem_mb", 512u64)
                .build(),
        )
        .unwrap()
    });
    h1.join().unwrap();
    // project b sees no jobs
    let jobs = get_json(addr, "/jobs", &t2).unwrap();
    assert!(jobs.as_array().unwrap().is_empty());
}
