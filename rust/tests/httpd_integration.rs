//! The `/v1` REST edge over real sockets: authenticate + route
//! (paper §4.1/Figure 7), the async job lifecycle (202 + poll + log
//! streaming), the uniform error envelope, and httpd robustness
//! against malformed/hostile input.

use std::io::{BufRead, BufReader, Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::{Duration, Instant};

use acai::api::dto::b64_encode;
use acai::api::make_handler;
use acai::api::router::percent_encode;
use acai::httpd::{get_json, post_json, request, Server};
use acai::json::Json;
use acai::Acai;

fn serve() -> (Arc<Acai>, Server, String) {
    let acai = Arc::new(Acai::boot_default());
    let root = acai.credentials.root_token().to_string();
    let server = Server::serve(0, make_handler(acai.clone())).unwrap();
    (acai, server, root)
}

fn bootstrap(addr: std::net::SocketAddr, root: &str, name: &str) -> String {
    let resp = post_json(
        addr,
        "/v1/projects",
        "",
        &Json::obj()
            .field("root_token", root)
            .field("name", name)
            .field("admin", "alice")
            .build(),
    )
    .unwrap();
    resp.get("admin_token")
        .and_then(Json::as_str)
        .unwrap()
        .to_string()
}

fn job_body(i: usize) -> Json {
    Json::obj()
        .field("name", format!("job-{i}"))
        .field("command", "python train_mnist.py --epoch 1")
        .field("output_fileset", format!("out-{i}"))
        .field("vcpus", 0.5)
        .field("mem_mb", 512u64)
        .build()
}

/// Poll a job to a terminal state over HTTP.
fn wait_terminal(addr: std::net::SocketAddr, token: &str, job: &str) -> Json {
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let v = get_json(addr, &format!("/v1/jobs/{job}"), token).unwrap();
        let state = v.get("state").and_then(Json::as_str).unwrap().to_string();
        if matches!(state.as_str(), "finished" | "failed" | "killed") {
            return v;
        }
        assert!(Instant::now() < deadline, "job {job} never finished");
        std::thread::sleep(Duration::from_millis(2));
    }
}

#[test]
fn bootstrap_project_then_full_flow_over_http() {
    let (_acai, server, root) = serve();
    let addr = server.addr();
    let token = bootstrap(addr, &root, "nlp");

    // project admin creates a second user
    let resp = post_json(
        addr,
        "/v1/users",
        &token,
        &Json::obj().field("name", "bob").build(),
    )
    .unwrap();
    assert!(resp.get("token").and_then(Json::as_str).is_some());

    // upload data (base64 over the wire) + build a file set
    let resp = post_json(
        addr,
        "/v1/files",
        &token,
        &Json::obj()
            .field(
                "files",
                Json::Arr(vec![Json::obj()
                    .field("path", "/data/train.bin")
                    .field("content_b64", b64_encode(b"train-data"))
                    .build()]),
            )
            .build(),
    )
    .unwrap();
    let uploaded = resp.get("files").and_then(Json::as_array).unwrap();
    assert_eq!(uploaded[0].get("version").and_then(Json::as_u64), Some(1));

    post_json(
        addr,
        "/v1/filesets",
        &token,
        &Json::obj()
            .field("name", "corpus")
            .field("specs", Json::Arr(vec![Json::from("/data/train.bin")]))
            .build(),
    )
    .unwrap();

    // async submit: 202, then poll to completion
    let body = Json::obj()
        .field("name", "http-train")
        .field("command", "python train_mnist.py --epoch 2")
        .field("input_fileset", "corpus")
        .field("output_fileset", "model")
        .field("vcpus", 1.0)
        .field("mem_mb", 1024u64)
        .build();
    let resp = request(
        addr,
        "POST",
        "/v1/jobs",
        &[("x-acai-token", token.as_str()), ("content-type", "application/json")],
        body.encode().as_bytes(),
    )
    .unwrap();
    assert_eq!(resp.status, 202, "{}", String::from_utf8_lossy(&resp.body));
    let v = acai::json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
    let job = v.get("job").and_then(Json::as_str).unwrap().to_string();
    let done = wait_terminal(addr, &token, &job);
    assert_eq!(done.get("state").and_then(Json::as_str), Some("finished"));
    assert!(done.get("runtime_secs").and_then(Json::as_f64).unwrap() > 0.0);

    // paginated job listing
    let jobs = get_json(addr, "/v1/jobs", &token).unwrap();
    assert_eq!(jobs.get("items").and_then(Json::as_array).unwrap().len(), 1);

    // metadata by strict kind
    let meta = get_json(addr, &format!("/v1/metadata/jobs/{job}"), &token).unwrap();
    assert_eq!(meta.get("state").and_then(Json::as_str), Some("finished"));

    // provenance graph records the output file set
    let graph = get_json(addr, "/v1/provenance", &token).unwrap();
    let nodes = graph.get("nodes").and_then(Json::as_array).unwrap();
    assert!(nodes.iter().any(|n| n.as_str() == Some("model:1")));

    // download a produced file through the percent-encoded path route
    let file = get_json(
        addr,
        &format!("/v1/files/{}", percent_encode("/model/mlp.bin")),
        &token,
    )
    .unwrap();
    assert!(!file.get("content_b64").and_then(Json::as_str).unwrap().is_empty());

    // versions listing of the uploaded file
    let versions = get_json(
        addr,
        &format!("/v1/files/{}/versions", percent_encode("/data/train.bin")),
        &token,
    )
    .unwrap();
    assert_eq!(
        versions.get("items").and_then(Json::as_array).unwrap().len(),
        1
    );

    // per-route metrics were collected
    let metrics = get_json(addr, "/v1/metrics", &token).unwrap();
    let routes = metrics.get("routes").and_then(Json::as_array).unwrap();
    assert!(routes
        .iter()
        .any(|r| r.get("route").and_then(Json::as_str) == Some("POST /v1/jobs")));
    // ...alongside the cluster's autoscaler/preemption counter block
    let cluster = metrics.get("cluster").expect("cluster counters");
    assert!(cluster.get("containers_launched").and_then(Json::as_u64).unwrap() >= 1);
    assert_eq!(
        cluster.get("nodes_preempted").and_then(Json::as_u64),
        Some(0),
        "no spot pools configured: nothing may be preempted"
    );
    for key in ["scale_up_events", "scale_down_events", "placement_failures"] {
        assert!(cluster.get(key).and_then(Json::as_u64).is_some(), "{key}");
    }
}

#[test]
fn concurrent_submissions_return_202_and_stream_logs_incrementally() {
    let (_acai, server, root) = serve();
    let addr = server.addr();
    let token = bootstrap(addr, &root, "bulk");

    // N jobs submitted concurrently over HTTP; every response is an
    // immediate 202 (the engine is never driven in-request)
    const N: usize = 6;
    let handles: Vec<_> = (0..N)
        .map(|i| {
            let token = token.clone();
            std::thread::spawn(move || {
                let resp = request(
                    addr,
                    "POST",
                    "/v1/jobs",
                    &[
                        ("x-acai-token", token.as_str()),
                        ("content-type", "application/json"),
                    ],
                    job_body(i).encode().as_bytes(),
                )
                .unwrap();
                assert_eq!(resp.status, 202, "{}", String::from_utf8_lossy(&resp.body));
                let v = acai::json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
                v.get("job").and_then(Json::as_str).unwrap().to_string()
            })
        })
        .collect();
    let ids: Vec<String> = handles.into_iter().map(|h| h.join().unwrap()).collect();

    // poll every job to completion, then read logs incrementally
    for job in &ids {
        let done = wait_terminal(addr, &token, job);
        assert_eq!(done.get("state").and_then(Json::as_str), Some("finished"));

        let chunk = get_json(addr, &format!("/v1/jobs/{job}/logs?offset=0"), &token).unwrap();
        let lines = chunk.get("lines").and_then(Json::as_array).unwrap();
        assert!(!lines.is_empty(), "{job} has no logs");
        let next = chunk.get("next_offset").and_then(Json::as_u64).unwrap();
        assert_eq!(next as usize, lines.len());

        // a second fetch from the cursor returns only what is new (nothing)
        let tail = get_json(
            addr,
            &format!("/v1/jobs/{job}/logs?offset={next}"),
            &token,
        )
        .unwrap();
        assert!(tail.get("lines").and_then(Json::as_array).unwrap().is_empty());
        // and a mid-stream offset returns the strict suffix
        let mid = get_json(addr, &format!("/v1/jobs/{job}/logs?offset=1"), &token).unwrap();
        assert_eq!(
            mid.get("lines").and_then(Json::as_array).unwrap().len(),
            lines.len() - 1
        );
    }

    // pagination walks all N jobs in order
    let mut seen = Vec::new();
    let mut after = String::new();
    loop {
        let path = if after.is_empty() {
            "/v1/jobs?limit=2".to_string()
        } else {
            format!("/v1/jobs?limit=2&after={after}")
        };
        let page = get_json(addr, &path, &token).unwrap();
        for item in page.get("items").and_then(Json::as_array).unwrap() {
            seen.push(item.get("job").and_then(Json::as_str).unwrap().to_string());
        }
        match page.get("next").and_then(Json::as_str) {
            Some(cursor) => after = cursor.to_string(),
            None => break,
        }
    }
    assert_eq!(seen.len(), N);
    let mut sorted = ids.clone();
    sorted.sort_by_key(|s| s.trim_start_matches("job-").parse::<u64>().unwrap());
    assert_eq!(seen, sorted);
}

#[test]
fn error_envelope_is_uniform_with_correct_statuses() {
    let (_acai, server, root) = serve();
    let addr = server.addr();
    let token = bootstrap(addr, &root, "errs");

    let envelope = |resp: &acai::httpd::Response| -> (String, String) {
        let v = acai::json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        let e = v.get("error").expect("envelope").clone();
        assert!(
            e.get("request_id").and_then(Json::as_str).is_some(),
            "missing request_id: {}",
            v.encode()
        );
        (
            e.get("code").and_then(Json::as_str).unwrap().to_string(),
            e.get("message").and_then(Json::as_str).unwrap().to_string(),
        )
    };
    let auth: [(&str, &str); 1] = [("x-acai-token", token.as_str())];

    // 401: no token
    let resp = request(addr, "GET", "/v1/jobs", &[], b"").unwrap();
    assert_eq!(resp.status, 401);
    assert_eq!(envelope(&resp).0, "unauthorized");

    // 401: forged token
    let resp = request(addr, "GET", "/v1/jobs", &[("x-acai-token", "forged")], b"").unwrap();
    assert_eq!(resp.status, 401);

    // 403: wrong root token on bootstrap
    let body = Json::obj()
        .field("root_token", "wrong")
        .field("name", "x")
        .field("admin", "a")
        .build();
    let resp = request(addr, "POST", "/v1/projects", &[], body.encode().as_bytes()).unwrap();
    assert_eq!(resp.status, 403);
    assert_eq!(envelope(&resp).0, "forbidden");

    // 404: unknown path
    let resp = request(addr, "GET", "/v1/nope", &auth, b"").unwrap();
    assert_eq!(resp.status, 404);
    assert_eq!(envelope(&resp).0, "not_found");

    // 404: unknown job id
    let resp = request(addr, "GET", "/v1/jobs/job-999", &auth, b"").unwrap();
    assert_eq!(resp.status, 404);

    // 405: known path, wrong method — with an allow header
    let resp = request(addr, "DELETE", "/v1/jobs", &auth, b"").unwrap();
    assert_eq!(resp.status, 405);
    assert_eq!(envelope(&resp).0, "method_not_allowed");
    let allow = resp
        .headers
        .iter()
        .find(|(k, _)| k == "allow")
        .map(|(_, v)| v.as_str())
        .unwrap();
    assert!(allow.contains("GET") && allow.contains("POST"), "{allow}");

    // 400: unknown metadata kind (the seed silently mapped this to Job)
    let resp = request(addr, "GET", "/v1/metadata/experiments/job-1", &auth, b"").unwrap();
    assert_eq!(resp.status, 400);
    let (code, message) = envelope(&resp);
    assert_eq!(code, "invalid");
    assert!(message.contains("experiments"), "{message}");

    // 400: unknown field in a DTO (no silent defaults)
    let body = Json::obj()
        .field("name", "j")
        .field("command", "python t.py --epoch 1")
        .field("output_fileset", "o")
        .field("vcpus", 1.0)
        .field("mem_mb", 512u64)
        .field("vcpu_count", 4.0)
        .build();
    let resp = request(
        addr,
        "POST",
        "/v1/jobs",
        &[
            ("x-acai-token", token.as_str()),
            ("content-type", "application/json"),
        ],
        body.encode().as_bytes(),
    )
    .unwrap();
    assert_eq!(resp.status, 400);
    assert!(envelope(&resp).1.contains("vcpu_count"));

    // 400: missing required field is an error, not a default
    let resp = request(
        addr,
        "POST",
        "/v1/jobs",
        &[
            ("x-acai-token", token.as_str()),
            ("content-type", "application/json"),
        ],
        Json::obj().field("name", "j").build().encode().as_bytes(),
    )
    .unwrap();
    assert_eq!(resp.status, 400);

    // every response carries the x-request-id header
    let resp = request(addr, "GET", "/v1/nope", &auth, b"").unwrap();
    assert!(resp.headers.iter().any(|(k, _)| k == "x-request-id"));
}

#[test]
fn concurrent_clients_are_isolated_by_token() {
    let (_acai, server, root) = serve();
    let addr = server.addr();
    let t1 = bootstrap(addr, &root, "a");
    let t2 = bootstrap(addr, &root, "b");

    let resp = post_json(addr, "/v1/jobs", &t1, &job_body(0)).unwrap();
    let job = resp.get("job").and_then(Json::as_str).unwrap().to_string();
    wait_terminal(addr, &t1, &job);

    // project b sees no jobs — and cannot read project a's job by id
    let jobs = get_json(addr, "/v1/jobs", &t2).unwrap();
    assert!(jobs.get("items").and_then(Json::as_array).unwrap().is_empty());
    let resp = request(
        addr,
        "GET",
        &format!("/v1/jobs/{job}"),
        &[("x-acai-token", t2.as_str())],
        b"",
    )
    .unwrap();
    assert_eq!(resp.status, 404);
    let resp = request(
        addr,
        "GET",
        &format!("/v1/jobs/{job}/logs"),
        &[("x-acai-token", t2.as_str())],
        b"",
    )
    .unwrap();
    assert_eq!(resp.status, 404);
}

// ---------------------------------------------------------------------
// httpd robustness (satellite: malformed request line, oversized body,
// missing content-length, concurrent keep-alive connections)
// ---------------------------------------------------------------------

/// Read one HTTP response off a raw socket; returns (status, body).
fn read_raw_response(reader: &mut BufReader<TcpStream>) -> (u16, String) {
    let mut status_line = String::new();
    reader.read_line(&mut status_line).unwrap();
    let status: u16 = status_line.split_whitespace().nth(1).unwrap().parse().unwrap();
    let mut len = 0usize;
    loop {
        let mut h = String::new();
        reader.read_line(&mut h).unwrap();
        let h = h.trim_end().to_ascii_lowercase();
        if h.is_empty() {
            break;
        }
        if let Some(v) = h.strip_prefix("content-length:") {
            len = v.trim().parse().unwrap();
        }
    }
    let mut body = vec![0u8; len];
    reader.read_exact(&mut body).unwrap();
    (status, String::from_utf8_lossy(&body).to_string())
}

#[test]
fn malformed_request_line_is_400() {
    let (_acai, server, _root) = serve();
    let mut stream = TcpStream::connect(server.addr()).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    stream.write_all(b"GARBAGE\r\n\r\n").unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let (status, body) = read_raw_response(&mut reader);
    assert_eq!(status, 400);
    assert!(body.contains("\"error\""), "{body}");
}

#[test]
fn oversized_body_is_rejected_without_reading_it() {
    let (_acai, server, _root) = serve();
    let mut stream = TcpStream::connect(server.addr()).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    // claim a 33 MiB body (limit is 32 MiB) but send none of it: the
    // server must answer 400 from the header alone
    stream
        .write_all(b"POST /v1/jobs HTTP/1.1\r\ncontent-length: 34603008\r\n\r\n")
        .unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let (status, body) = read_raw_response(&mut reader);
    assert_eq!(status, 400);
    assert!(body.contains("too large"), "{body}");
}

#[test]
fn missing_content_length_means_empty_body() {
    let (_acai, server, root) = serve();
    let addr = server.addr();
    let token = bootstrap(addr, &root, "nolen");
    // POST with a body but no content-length: the body is not read, so
    // the handler sees an empty (invalid JSON) payload -> 400, and the
    // connection is NOT poisoned for the next request
    let mut stream = TcpStream::connect(addr).unwrap();
    stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
    let req = format!(
        "POST /v1/filesets HTTP/1.1\r\nx-acai-token: {token}\r\n\r\n"
    );
    stream.write_all(req.as_bytes()).unwrap();
    let mut reader = BufReader::new(stream.try_clone().unwrap());
    let (status, body) = read_raw_response(&mut reader);
    assert_eq!(status, 400, "{body}");
}

#[test]
fn stalled_client_does_not_block_other_requests() {
    // slow-loris liveness: a client that sends half a request line and
    // stalls must not pin a worker — the probe/park design hands the
    // connection back to the queue, so everyone else keeps being served
    let (_acai, server, _root) = serve();
    let addr = server.addr();

    let mut loris = TcpStream::connect(addr).unwrap();
    loris.write_all(b"GET /v1/healthz HT").unwrap();
    // no more bytes: the request line never completes

    let start = Instant::now();
    let handles: Vec<_> = (0..4)
        .map(|_| {
            std::thread::spawn(move || {
                let mut stream = TcpStream::connect(addr).unwrap();
                stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                for _ in 0..5 {
                    stream
                        .write_all(b"GET /v1/healthz HTTP/1.1\r\ncontent-length: 0\r\n\r\n")
                        .unwrap();
                    let (status, _) = read_raw_response(&mut reader);
                    assert_eq!(status, 200);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
    assert!(
        start.elapsed() < Duration::from_secs(5),
        "stalled client starved the pool: {:?}",
        start.elapsed()
    );
    drop(loris);
    // give the pool a beat to notice the loris hangup before the
    // server (and its worker threads) are torn down
    std::thread::sleep(Duration::from_millis(50));
}

#[test]
fn concurrent_keep_alive_connections_serve_sequential_requests() {
    let (_acai, server, _root) = serve();
    let addr = server.addr();
    let handles: Vec<_> = (0..4)
        .map(|_| {
            std::thread::spawn(move || {
                let mut stream = TcpStream::connect(addr).unwrap();
                stream.set_read_timeout(Some(Duration::from_secs(5))).unwrap();
                let mut reader = BufReader::new(stream.try_clone().unwrap());
                for _ in 0..5 {
                    stream
                        .write_all(b"GET /v1/healthz HTTP/1.1\r\ncontent-length: 0\r\n\r\n")
                        .unwrap();
                    let (status, body) = read_raw_response(&mut reader);
                    assert_eq!(status, 200);
                    assert!(body.contains("ok"), "{body}");
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
}

#[test]
fn raw_download_streams_chunk_windows_as_octet_stream() {
    let (_acai, server, root) = serve();
    let addr = server.addr();
    let token = bootstrap(addr, &root, "rawdl");

    // multi-chunk body (64 KiB chunks) so the response tail is several
    // shared windows, proving the content-length framing covers them
    let body: Vec<u8> = (0u8..=250).cycle().take(150_000).collect();
    post_json(
        addr,
        "/v1/files",
        &token,
        &Json::obj()
            .field(
                "files",
                Json::Arr(vec![Json::obj()
                    .field("path", "/data/raw.bin")
                    .field("content_b64", b64_encode(&body))
                    .build()]),
            )
            .build(),
    )
    .unwrap();

    let path = format!("/v1/files/{}?raw", percent_encode("/data/raw.bin"));
    let resp = request(addr, "GET", &path, &[("x-acai-token", &token)], b"").unwrap();
    assert_eq!(resp.status, 200);
    assert_eq!(resp.header("content-type"), Some("application/octet-stream"));
    // byte-identical, no base64 envelope
    assert_eq!(resp.body, body);

    // raw + range is rejected — ranged reads stay on the JSON path
    let path = format!("/v1/files/{}?raw&offset=0&len=10", percent_encode("/data/raw.bin"));
    let resp = request(addr, "GET", &path, &[("x-acai-token", &token)], b"").unwrap();
    assert_eq!(resp.status, 400);
}
