//! API conformance: the in-process [`Client`] and the wire
//! [`RemoteClient`] implement the same [`AcaiApi`] trait and must pass
//! the **same** behavioral suite — upload/download, file sets,
//! pagination, the async job lifecycle with incremental logs,
//! metadata, provenance, profiling/provisioning, and typed error
//! statuses.  Running the suite over HTTP is what proves every DTO
//! codec round-trips.

use std::sync::Arc;

use acai::api::dto::{JobTrace, PageReq, PoolSpec, TraceDir};
use acai::api::{make_handler, TenantConfig};
use acai::autoprovision::Objective;
use acai::cluster::{ClusterConfig, NodeSpec, ResourceConfig};
use acai::datalake::metadata::ArtifactKind;
use acai::docstore::Clause;
use acai::engine::{ExperimentSpec, MetricMode, SweepStrategy};
use acai::httpd::{HttpConn, Server};
use acai::ids::{ExperimentId, JobId};
use acai::json::Json;
use acai::sdk::{AcaiApi, Client, JobRequest, RemoteClient};
use acai::{Acai, PlatformConfig};

fn page(limit: usize, after: Option<String>) -> PageReq {
    PageReq { limit, after }
}

fn job_request(name: &str, input: &str, output: &str) -> JobRequest {
    JobRequest {
        name: name.into(),
        command: "python train_mnist.py --epoch 2".into(),
        input_fileset: input.into(),
        output_fileset: output.into(),
        resources: ResourceConfig::new(1.0, 1024),
        pool: None,
        data_commit: None,
        priority: acai::engine::Priority::Normal,
        gang: 1,
    }
}

fn experiment_spec(name: &str, template: &str, input: &str) -> ExperimentSpec {
    ExperimentSpec {
        name: name.into(),
        template: template.into(),
        input_fileset: input.into(),
        strategy: SweepStrategy::Grid,
        resources: ResourceConfig::new(1.0, 1024),
        profile: None,
        objective: None,
        pool: None,
        data_commit: None,
    }
}

/// The shared behavioral suite.  Every assertion here holds for both
/// clients; `api` is the only platform handle the suite touches.
fn conformance_suite(api: &dyn AcaiApi) {
    // ---- upload / download round trip ----
    let uploaded = api
        .upload(&[("/data/a.bin", b"alpha"), ("/data/b.bin", b"beta")])
        .unwrap();
    assert_eq!(uploaded.len(), 2);
    assert!(uploaded.iter().all(|e| e.version == 1));
    assert_eq!(api.fetch("/data/a.bin", None).unwrap(), b"alpha");
    assert_eq!(api.fetch("/data/a.bin", Some(1)).unwrap(), b"alpha");

    // second version of a path
    api.upload(&[("/data/a.bin", b"alpha-2")]).unwrap();
    assert_eq!(api.fetch("/data/a.bin", None).unwrap(), b"alpha-2");
    assert_eq!(api.fetch("/data/a.bin", Some(1)).unwrap(), b"alpha");
    let versions = api.file_versions("/data/a.bin", &page(10, None)).unwrap();
    assert_eq!(versions.items, vec![1, 2]);
    assert!(versions.next.is_none());

    // ---- data plane: ranged download + chunk manifest + dedup ----
    assert_eq!(api.fetch_range("/data/a.bin", None, 2, Some(3)).unwrap(), b"pha");
    assert_eq!(api.fetch_range("/data/a.bin", Some(1), 3, None).unwrap(), b"ha");
    assert_eq!(api.fetch_range("/data/a.bin", None, 0, Some(999)).unwrap(), b"alpha-2");
    assert_eq!(api.fetch_range("/data/a.bin", None, 99, None).unwrap_err().status(), 400);
    assert_eq!(api.fetch_range("/nope.bin", None, 0, None).unwrap_err().status(), 404);
    let stat = api.file_stat("/data/a.bin", None).unwrap();
    assert_eq!(stat.path, "/data/a.bin");
    assert_eq!(stat.version, 2);
    assert_eq!(stat.size, 7);
    assert!(stat.chunk_size > 0);
    assert_eq!(stat.chunks.len(), 1, "7 bytes fit one chunk");
    assert_ne!(
        api.file_stat("/data/a.bin", Some(1)).unwrap().chunks,
        stat.chunks,
        "different content, different chunk ids"
    );
    assert_eq!(api.file_stat("/nope.bin", None).unwrap_err().status(), 404);
    // identical bytes uploaded under a new path store nothing new
    let before = api.data_metrics().unwrap();
    api.upload(&[("/dup/a-copy.bin", b"alpha-2")]).unwrap();
    let after = api.data_metrics().unwrap();
    assert_eq!(after.stored_bytes, before.stored_bytes, "dedup across paths");
    assert_eq!(after.logical_bytes, before.logical_bytes + 7);
    assert!(after.dedup_hits > before.dedup_hits);
    assert!(after.dedup_ratio() > before.dedup_ratio());
    assert_eq!(
        api.file_stat("/dup/a-copy.bin", None).unwrap().chunks,
        stat.chunks,
        "identical content resolves to the same chunk ids"
    );

    // ---- file listing with cursor pagination ----
    let p1 = api.files("/data", &page(1, None)).unwrap();
    assert_eq!(p1.items.len(), 1);
    assert_eq!(p1.items[0].path, "/data/a.bin");
    assert_eq!(p1.items[0].version, 2);
    let cursor = p1.next.clone().expect("more pages");
    let p2 = api.files("/data", &page(10, Some(cursor))).unwrap();
    assert_eq!(p2.items.len(), 1);
    assert_eq!(p2.items[0].path, "/data/b.bin");
    assert!(p2.next.is_none());

    // ---- file sets ----
    let v = api.make_file_set("corpus", &["/data/a.bin", "/data/b.bin"]).unwrap();
    assert_eq!(v, 1);
    let sets = api.file_sets(&page(10, None)).unwrap();
    assert_eq!(sets.items.len(), 1);
    assert_eq!(sets.items[0].path, "corpus");

    // ---- async job lifecycle ----
    let job = api.submit_job(&job_request("train", "corpus", "model")).unwrap();
    let status = api.await_job(job).unwrap();
    assert_eq!(status.state, "finished");
    assert_eq!(status.id, job);
    assert!(status.runtime_secs.unwrap() > 0.0);
    assert!(status.cost.unwrap() > 0.0);
    assert_eq!(status.output_version, Some(1));

    // incremental log streaming
    let chunk = api.job_logs(job, 0).unwrap();
    assert!(!chunk.lines.is_empty());
    assert_eq!(chunk.next_offset, chunk.lines.len());
    let tail = api.job_logs(job, chunk.next_offset).unwrap();
    assert!(tail.lines.is_empty());
    assert_eq!(tail.next_offset, chunk.next_offset);
    let mid = api.job_logs(job, 1).unwrap();
    assert_eq!(mid.lines.len(), chunk.lines.len() - 1);

    // job listing
    let jobs = api.jobs(&page(10, None)).unwrap();
    assert_eq!(jobs.items.len(), 1);
    assert_eq!(jobs.items[0].id, job);

    // ---- tracing: the lifecycle timeline crosses the boundary ----
    let trace = api.job_trace(job).unwrap();
    assert_eq!(trace.job, job);
    assert_eq!(trace.state, "finished");
    assert_eq!(trace.preemptions, 0);
    assert_eq!(trace.events.first().unwrap().name, "enqueue");
    assert_eq!(trace.events.last().unwrap().name, "complete");
    assert!(trace.events.iter().any(|e| e.name == "placement"));
    // per-trace ordinals are dense and events are time-ordered
    for (i, e) in trace.events.iter().enumerate() {
        assert_eq!(e.seq, i as u64);
    }
    for w in trace.events.windows(2) {
        assert!(w[0].at <= w[1].at, "timeline must be time-ordered");
    }
    // the phase durations account for the billed runtime
    let runtime = status.runtime_secs.unwrap();
    let replayed = trace.transfer + trace.run + trace.rework;
    assert!(
        (replayed - runtime).abs() < 1e-6 * runtime.max(1.0),
        "phases {replayed} must account for runtime {runtime}"
    );
    assert!(trace.queue_wait >= 0.0);
    // typed errors: unknown job and unknown request id are both 404
    assert_eq!(api.job_trace(JobId(99_999)).unwrap_err().status(), 404);
    assert_eq!(api.request_trace("ghost-rid").unwrap_err().status(), 404);

    // ---- metadata ----
    let doc = api.metadata_doc(ArtifactKind::Job, &job.to_string()).unwrap();
    assert_eq!(doc.get("state").and_then(Json::as_str), Some("finished"));
    let hits = api
        .metadata_query(ArtifactKind::Job, &[Clause::eq("name", "train")])
        .unwrap();
    assert_eq!(hits.len(), 1);
    assert_eq!(hits[0].0, job.to_string());

    api.tag_artifact(
        ArtifactKind::FileSet,
        "corpus:1",
        &[
            ("model".to_string(), Json::from("BERT")),
            ("precision".to_string(), Json::from(0.72)),
        ],
    )
    .unwrap();
    let hits = api
        .metadata_query(
            ArtifactKind::FileSet,
            &[Clause::eq("model", "BERT"), Clause::gte("precision", 0.5)],
        )
        .unwrap();
    assert_eq!(hits.len(), 1);
    assert_eq!(hits[0].0, "corpus:1");

    // tag validation is part of the shared contract: non-scalar values
    // and empty field lists are 400 on BOTH clients
    assert_eq!(
        api.tag_artifact(
            ArtifactKind::FileSet,
            "corpus:1",
            &[("runs".to_string(), Json::Arr(vec![Json::from(1u64)]))],
        )
        .unwrap_err()
        .status(),
        400
    );
    assert_eq!(
        api.tag_artifact(ArtifactKind::FileSet, "corpus:1", &[]).unwrap_err().status(),
        400
    );

    // ---- optimistic concurrency: the expected_version matrix ----
    // registration seeded version 1; the successful tag above bumped it
    let doc = api.metadata_doc(ArtifactKind::FileSet, "corpus:1").unwrap();
    let current = doc.get("version").and_then(Json::as_u64).unwrap();
    assert_eq!(current, 2);
    // matching guard: the write lands and bumps the version
    let bumped = api
        .tag_artifact_guarded(
            ArtifactKind::FileSet,
            "corpus:1",
            &[("stage".to_string(), Json::from("eval"))],
            Some(current),
        )
        .unwrap();
    assert_eq!(bumped, current + 1);
    // stale guard: 409 conflict, and the losing write changes nothing
    assert_eq!(
        api.tag_artifact_guarded(
            ArtifactKind::FileSet,
            "corpus:1",
            &[("stage".to_string(), Json::from("stale-loser"))],
            Some(current),
        )
        .unwrap_err()
        .status(),
        409
    );
    let doc = api.metadata_doc(ArtifactKind::FileSet, "corpus:1").unwrap();
    assert_eq!(doc.get("stage").and_then(Json::as_str), Some("eval"));
    assert_eq!(doc.get("version").and_then(Json::as_u64), Some(bumped));
    // absent guard: unconditional last-writer-wins, version still bumps
    let unconditional = api
        .tag_artifact_guarded(
            ArtifactKind::FileSet,
            "corpus:1",
            &[("stage".to_string(), Json::from("final"))],
            None,
        )
        .unwrap();
    assert_eq!(unconditional, bumped + 1);
    // the version field itself is a reserved tag key on both clients
    assert_eq!(
        api.tag_artifact(
            ArtifactKind::FileSet,
            "corpus:1",
            &[("version".to_string(), Json::from(99u64))],
        )
        .unwrap_err()
        .status(),
        400
    );

    // ---- provenance ----
    let (nodes, edges) = api.provenance().unwrap();
    assert!(nodes.contains(&"corpus:1".to_string()));
    assert!(nodes.contains(&"model:1".to_string()));
    assert!(edges.iter().any(|e| e.kind == "job_execution"));
    let back = api.trace("model", 1, TraceDir::Backward).unwrap();
    assert_eq!(back[0].from, "corpus:1");
    let fwd = api.trace("corpus", 1, TraceDir::Forward).unwrap();
    assert!(fwd.iter().any(|e| e.to == "model:1"));
    let lineage = api.lineage_of("model", 1).unwrap();
    assert!(lineage.contains(&"corpus:1".to_string()));

    // ---- profiler + auto-provisioner ----
    let template = api
        .profile_template("mnist", "python train_mnist.py --epoch {1,2,3}", "corpus")
        .unwrap();
    assert!(template.raw() >= 1);
    let choice = api
        .provision("mnist", &[20.0], Objective::MinCost { max_runtime: 200.0 })
        .unwrap();
    assert!(choice.predicted_runtime <= 200.0);
    assert!(choice.predicted_cost > 0.0);
    assert!(choice.config.vcpus >= 0.5);

    // ---- experiments: async sweep lifecycle ----
    let exp = api
        .create_experiment(&experiment_spec(
            "sweep",
            "python train_mnist.py --epoch {1,2} --learning-rate {0.1,0.3}",
            "corpus",
        ))
        .unwrap();
    assert_eq!(exp.trials, 4);
    let done = api.await_experiment(exp.id).unwrap();
    assert_eq!(done.state, "completed");
    assert_eq!(done.finished, 4);
    assert_eq!(done.failed, 0);

    // experiment listing + trial cursor pagination
    let exps = api.experiments(&page(10, None)).unwrap();
    assert!(exps.items.iter().any(|e| e.id == exp.id));
    let t1 = api.experiment_trials(exp.id, &page(3, None)).unwrap();
    assert_eq!(t1.items.len(), 3);
    let cursor = t1.next.clone().expect("more trials");
    let t2 = api.experiment_trials(exp.id, &page(10, Some(cursor))).unwrap();
    assert_eq!(t2.items.len(), 1);
    assert!(t2.next.is_none());
    for trial in t1.items.iter().chain(&t2.items) {
        assert_eq!(trial.state, "finished");
        assert!(trial.cost.unwrap() > 0.0);
        assert!(trial.metric("training_loss").is_some());
        assert!(trial.output.is_some(), "provenance anchor recorded");
        // every trial links to its job's span timeline
        let trace_id = trial.trace_id().expect("finished trials carry a trace id");
        assert_eq!(trace_id, trial.job.unwrap().to_string());
        assert_eq!(api.job_trace(trial.job.unwrap()).unwrap().state, "finished");
    }

    // deterministic best-trial selection: loss decays with epochs, and
    // the tie between the two epoch-2 points resolves to the lower index
    let best = api.best_trial(exp.id, "training_loss", MetricMode::Min).unwrap();
    assert_eq!(best.index, 2);
    assert_eq!(best.args[0], ("epoch".to_string(), 2.0));

    // per-trial auto-provisioning from the fitted "mnist" profile
    let mut prov_spec = experiment_spec(
        "provisioned",
        "python train_mnist.py --epoch {1,2}",
        "corpus",
    );
    prov_spec.profile = Some("mnist".into());
    prov_spec.objective = Some(Objective::MinCost { max_runtime: 200.0 });
    let prov = api.create_experiment(&prov_spec).unwrap();
    let prov_done = api.await_experiment(prov.id).unwrap();
    assert_eq!(prov_done.finished, 2);
    let prov_trials = api.experiment_trials(prov.id, &page(10, None)).unwrap();
    for trial in &prov_trials.items {
        assert!(trial.predicted_runtime.unwrap() <= 200.0);
        assert!(trial.predicted_cost.unwrap() > 0.0);
    }

    // ---- typed error statuses survive the boundary ----
    // page invariants are shared: limit 0 is a 400 on both clients
    assert_eq!(api.files("/", &page(0, None)).unwrap_err().status(), 400);
    assert_eq!(api.jobs(&page(0, None)).unwrap_err().status(), 400);
    assert_eq!(api.fetch("/nope.bin", None).unwrap_err().status(), 404);
    assert_eq!(api.file_versions("/nope.bin", &page(10, None)).unwrap_err().status(), 404);
    assert_eq!(api.job_status(JobId(99_999)).unwrap_err().status(), 404);
    assert_eq!(api.job_logs(JobId(99_999), 0).unwrap_err().status(), 404);
    // killing a finished job is a 409 conflict
    assert_eq!(api.kill_job(job).unwrap_err().status(), 409);
    // submitting against a missing input file set is a 404
    assert_eq!(
        api.submit_job(&job_request("bad", "ghost", "out")).unwrap_err().status(),
        404
    );
    // a nameless output file set is a 400
    assert_eq!(
        api.submit_job(&job_request("bad", "corpus", "")).unwrap_err().status(),
        400
    );
    // unknown profile template is a 404
    assert_eq!(
        api.provision("ghost", &[1.0], Objective::MinCost { max_runtime: 10.0 })
            .unwrap_err()
            .status(),
        404
    );
    // experiment errors: unknown ids 404, bad pages and specs 400
    assert_eq!(api.experiment(ExperimentId(99_999)).unwrap_err().status(), 404);
    assert_eq!(
        api.experiment_trials(ExperimentId(99_999), &page(10, None)).unwrap_err().status(),
        404
    );
    assert_eq!(
        api.best_trial(exp.id, "no-such-metric", MetricMode::Min).unwrap_err().status(),
        404
    );
    assert_eq!(api.experiments(&page(0, None)).unwrap_err().status(), 400);
    // a sweep template without hint sets cannot expand
    assert_eq!(
        api.create_experiment(&experiment_spec(
            "flat",
            "python train_mnist.py --epoch 3",
            "corpus"
        ))
        .unwrap_err()
        .status(),
        400
    );

    // ---- cluster surface: pools, nodes, admin upsert ----
    let pools = api.cluster_pools().unwrap();
    assert_eq!(pools.len(), 1);
    assert_eq!(pools[0].spec.name, "ondemand");
    assert_eq!(pools[0].spec.price_multiplier, 1.0);
    assert_eq!(pools[0].nodes, 8);
    assert_eq!(pools[0].preempted_nodes, 0);
    let nodes = api.cluster_nodes().unwrap();
    assert_eq!(nodes.len(), 8);
    assert!(nodes.iter().all(|n| n.pool == "ondemand" && n.vcpus == 16.0));
    // upsert a discounted (non-revocable) pool: min_nodes honored now
    let updated = api
        .put_cluster_pool(&PoolSpec {
            name: "batch".into(),
            vcpus: 4.0,
            mem_mb: 8192,
            bandwidth_mbps: 125.0,
            price_multiplier: 0.5,
            min_nodes: 2,
            max_nodes: 4,
            preemption_mean_secs: 0.0,
        })
        .unwrap();
    assert_eq!(updated.len(), 2);
    let batch = updated.iter().find(|p| p.spec.name == "batch").unwrap();
    assert_eq!(batch.nodes, 2);
    assert_eq!(api.cluster_nodes().unwrap().len(), 10);
    // a job pinned to the new pool runs there, billed at its multiplier
    let mut pinned = job_request("pinned", "corpus", "pinned-out");
    pinned.pool = Some("batch".into());
    let pinned_job = api.submit_job(&pinned).unwrap();
    let pinned_done = api.await_job(pinned_job).unwrap();
    assert_eq!(pinned_done.state, "finished");
    // same command/resources as the earlier on-demand "train" job: the
    // runtime matches and the cost is exactly the 0.5 multiplier
    let train_done = api.job_status(job).unwrap();
    // tolerances absorb the SimClock's microsecond rounding
    assert!(
        (pinned_done.runtime_secs.unwrap() - train_done.runtime_secs.unwrap()).abs() < 1e-4
    );
    assert!(
        (pinned_done.cost.unwrap() - 0.5 * train_done.cost.unwrap()).abs() < 1e-6,
        "batch-pool cost {} vs on-demand {}",
        pinned_done.cost.unwrap(),
        train_done.cost.unwrap()
    );
    // pool errors are typed on both clients: unknown pool 400,
    // malformed pool spec 400
    let mut ghost_pool = job_request("ghosted", "corpus", "gp-out");
    ghost_pool.pool = Some("no-such-pool".into());
    assert_eq!(api.submit_job(&ghost_pool).unwrap_err().status(), 400);
    assert_eq!(
        api.put_cluster_pool(&PoolSpec {
            name: "broken".into(),
            vcpus: 4.0,
            mem_mb: 8192,
            bandwidth_mbps: 125.0,
            price_multiplier: 0.5,
            min_nodes: 5,
            max_nodes: 2,
            preemption_mean_secs: 0.0,
        })
        .unwrap_err()
        .status(),
        400
    );
    // a pinned request bigger than its pool's node shape can never be
    // placed — rejected at submit, never queued forever
    let mut oversized = job_request("oversized", "corpus", "ov-out");
    oversized.pool = Some("batch".into());
    oversized.resources = ResourceConfig::new(8.0, 8192);
    assert_eq!(api.submit_job(&oversized).unwrap_err().status(), 400);

    // ---- tenancy: usage accounting is observable on both clients ----
    // (absolute counts differ — the wire client pays per HTTP request,
    // the in-process client per SDK call — so only invariants hold)
    let usage = api.tenant_usage().unwrap();
    assert!(!usage.project.is_empty());
    assert!(usage.requests > 0, "every admitted call was counted");
    assert!(usage.request_bytes + usage.response_bytes > 0, "transfers were metered");
    assert_eq!(usage.throttled, 0, "permissive defaults never throttle");
    assert_eq!(usage.rejected, 0);
    assert!(usage.api_cost > 0.0, "usage prices into a positive bill");
}

#[test]
fn in_process_client_conforms() {
    let acai = Arc::new(Acai::boot_default());
    let root = acai.credentials.root_token().to_string();
    let (_p, token) = acai.credentials.create_project(&root, "conf", "alice").unwrap();
    let client = Client::connect(acai.clone(), &token).unwrap();
    conformance_suite(&client);
}

#[test]
fn remote_client_conforms() {
    let acai = Arc::new(Acai::boot_default());
    let root = acai.credentials.root_token().to_string();
    let server = Server::serve(0, make_handler(acai.clone())).unwrap();
    let (_project, remote) =
        RemoteClient::create_project(server.addr(), &root, "conf-remote", "alice").unwrap();
    conformance_suite(&remote);
}

#[test]
fn remote_connect_validates_tokens() {
    let acai = Arc::new(Acai::boot_default());
    let root = acai.credentials.root_token().to_string();
    let server = Server::serve(0, make_handler(acai.clone())).unwrap();
    assert_eq!(
        RemoteClient::connect(server.addr(), "forged").unwrap_err().status(),
        401
    );
    let (_p, token) = acai.credentials.create_project(&root, "p", "u").unwrap();
    assert!(RemoteClient::connect(server.addr(), token).is_ok());
}

/// The acceptance sweep: 100 trials through the experiment surface.
/// Returns (winner index, winner metric) so the two client runs can be
/// compared for determinism.
fn hundred_trial_sweep(api: &dyn AcaiApi) -> (usize, f64) {
    api.upload(&[("/data/corpus.bin", b"bytes")]).unwrap();
    api.make_file_set("data", &["/data/corpus.bin"]).unwrap();
    let exp = api
        .create_experiment(&experiment_spec(
            "century",
            "python train_mnist.py \
             --epoch {1,2,3,4,5,6,7,8,9,10} \
             --learning-rate {0.05,0.1,0.15,0.2,0.25,0.3,0.35,0.4,0.45,0.5}",
            "data",
        ))
        .unwrap();
    assert_eq!(exp.trials, 100);
    let done = api.await_experiment(exp.id).unwrap();
    assert_eq!(done.state, "completed");
    assert_eq!(done.finished, 100);
    // every trial record persisted with metrics, billing and provenance
    let mut seen = 0usize;
    let mut cursor: Option<String> = None;
    loop {
        let out = api.experiment_trials(exp.id, &page(37, cursor.clone())).unwrap();
        for trial in &out.items {
            assert_eq!(trial.index, seen);
            seen += 1;
            assert_eq!(trial.state, "finished");
            assert!(trial.cost.unwrap() > 0.0);
            assert!(trial.metric("training_loss").is_some());
            assert!(trial.output.is_some());
        }
        match out.next {
            Some(c) => cursor = Some(c),
            None => break,
        }
    }
    assert_eq!(seen, 100);
    let best = api.best_trial(exp.id, "training_loss", MetricMode::Min).unwrap();
    (best.index, best.metric("training_loss").unwrap())
}

#[test]
fn hundred_trial_sweep_is_deterministic_across_clients() {
    // in-process client on a fresh platform
    let acai = Arc::new(Acai::boot_default());
    let root = acai.credentials.root_token().to_string();
    let (_p, token) = acai.credentials.create_project(&root, "c100", "alice").unwrap();
    let client = Client::connect(acai.clone(), &token).unwrap();
    let local = hundred_trial_sweep(&client);

    // remote client on its own fresh platform behind real HTTP
    let acai2 = Arc::new(Acai::boot_default());
    let root2 = acai2.credentials.root_token().to_string();
    let server = Server::serve(0, make_handler(acai2.clone())).unwrap();
    let (_proj, remote) =
        RemoteClient::create_project(server.addr(), &root2, "c100", "alice").unwrap();
    let wire = hundred_trial_sweep(&remote);

    assert_eq!(local.0, wire.0, "winner index must agree across clients");
    assert!((local.1 - wire.1).abs() < 1e-12, "winner metric must agree");
    // grid order: epoch varies slowest, so indices 90..=99 are the
    // epoch-10 points; their losses tie and the lowest index wins
    assert_eq!(local.0, 90);
}

#[test]
fn remote_kill_interrupts_a_queued_job() {
    // kill through the wire: submit a burst so at least the last jobs
    // sit in the queue, then kill one before it can finish
    let acai = Arc::new(Acai::boot_default());
    let root = acai.credentials.root_token().to_string();
    let server = Server::serve(0, make_handler(acai.clone())).unwrap();
    let (_project, remote) =
        RemoteClient::create_project(server.addr(), &root, "killer", "alice").unwrap();

    let mut last = None;
    for i in 0..4 {
        let id = remote
            .submit_job(&job_request(&format!("burst-{i}"), "", &format!("b{i}-out")))
            .unwrap();
        last = Some(id);
    }
    let id = last.unwrap();
    // the job is either still live (kill succeeds -> killed) or already
    // finished (kill conflicts with 409) — both prove typed errors and
    // state transitions cross the wire
    match remote.kill_job(id) {
        Ok(()) => {
            let status = remote.await_job(id).unwrap();
            assert_eq!(status.state, "killed");
        }
        Err(e) => assert_eq!(e.status(), 409),
    }
}

/// ISSUE-4 acceptance: run a seeded spot-pool sweep and the identical
/// sweep on on-demand capacity.  Returns the bit patterns of both total
/// costs plus the spot revocation count, so two runs (and the two
/// clients) can be compared for exact determinism.
fn spot_sweep_outcome(api: &dyn AcaiApi) -> (u64, u64, u64) {
    api.upload(&[("/data/corpus.bin", b"bytes")]).unwrap();
    api.make_file_set("data", &["/data/corpus.bin"]).unwrap();
    // cheap revocable capacity next to the default on-demand pool
    api.put_cluster_pool(&PoolSpec {
        name: "spot".into(),
        vcpus: 4.0,
        mem_mb: 8192,
        bandwidth_mbps: 125.0,
        price_multiplier: 0.3,
        min_nodes: 0,
        max_nodes: 6,
        preemption_mean_secs: 6.0,
    })
    .unwrap();

    let template = "python train_mnist.py --epoch {5,6,7,8,9} --learning-rate {0.1,0.3}";
    let sweep_cost = |name: &str, pool: &str| -> f64 {
        let mut spec = experiment_spec(name, template, "data");
        spec.pool = Some(pool.to_string());
        let exp = api.create_experiment(&spec).unwrap();
        assert_eq!(exp.trials, 10);
        let done = api.await_experiment(exp.id).unwrap();
        assert_eq!(done.state, "completed");
        assert_eq!(done.finished, 10, "every trial must survive the storm");
        assert_eq!(done.failed, 0);
        let mut total = 0.0f64;
        let mut cursor: Option<String> = None;
        loop {
            let out = api.experiment_trials(exp.id, &page(7, cursor.clone())).unwrap();
            for trial in &out.items {
                assert_eq!(trial.state, "finished");
                total += trial.cost.unwrap();
            }
            match out.next {
                Some(c) => cursor = Some(c),
                None => break,
            }
        }
        total
    };

    let spot_cost = sweep_cost("storm", "spot");
    // the storm was real: at least 5 spot nodes revoked mid-sweep
    let preempted: u64 = api
        .cluster_pools()
        .unwrap()
        .iter()
        .map(|p| p.preempted_nodes)
        .sum();
    assert!(preempted >= 5, "want a real storm, saw {preempted} revocations");

    let ondemand_cost = sweep_cost("calm", "ondemand");
    // the paper's cost story: revocable capacity + checkpointed
    // rescheduling beats on-demand even after paying the rework
    assert!(
        spot_cost < ondemand_cost,
        "spot sweep {spot_cost} must undercut on-demand {ondemand_cost}"
    );
    (spot_cost.to_bits(), ondemand_cost.to_bits(), preempted)
}

fn spot_outcome_in_process() -> (u64, u64, u64) {
    let acai = Arc::new(Acai::boot_default());
    let root = acai.credentials.root_token().to_string();
    let (_p, token) = acai.credentials.create_project(&root, "spot", "alice").unwrap();
    let client = Client::connect(acai, &token).unwrap();
    spot_sweep_outcome(&client)
}

fn spot_outcome_over_the_wire() -> (u64, u64, u64) {
    let acai = Arc::new(Acai::boot_default());
    let root = acai.credentials.root_token().to_string();
    let server = Server::serve(0, make_handler(acai)).unwrap();
    let (_proj, remote) =
        RemoteClient::create_project(server.addr(), &root, "spot", "alice").unwrap();
    spot_sweep_outcome(&remote)
}

#[test]
fn seeded_spot_sweep_is_cheaper_and_deterministic_in_process() {
    let a = spot_outcome_in_process();
    let b = spot_outcome_in_process();
    assert_eq!(a, b, "same seed must replay the same storm bit-for-bit");
}

#[test]
fn seeded_spot_sweep_is_cheaper_and_deterministic_over_the_wire() {
    let a = spot_outcome_over_the_wire();
    let b = spot_outcome_over_the_wire();
    assert_eq!(a, b, "same seed must replay the same storm over HTTP");
    // and the wire changes nothing: the in-process platform sees the
    // exact same placement, preemption sequence, and bill
    assert_eq!(a, spot_outcome_in_process());
}

/// Lifetime request cap for the throttling acceptance tests.
const QUOTA: u64 = 40;

/// A restrictive tenant policy: 200 req/s with a burst of 2 (so
/// back-to-back calls throttle immediately but refill within ~5ms),
/// plus a lifetime cap of [`QUOTA`] admitted requests.
fn throttled_config() -> PlatformConfig {
    PlatformConfig {
        tenant: TenantConfig {
            rate_limit_rps: 200.0,
            rate_limit_burst: 2.0,
            request_quota: Some(QUOTA),
            byte_quota: None,
        },
        ..PlatformConfig::default()
    }
}

/// ISSUE-6 acceptance, shared across clients: transient rate limiting
/// is absorbed transparently (the in-process client waits out the
/// refill; the remote client obeys `retry-after` and re-sends), while
/// quota exhaustion surfaces as a hard 429 — and usage stays
/// observable throughout because `GET /v1/tenant` is admission-exempt.
fn throttled_suite(api: &dyn AcaiApi) {
    // burst 2 at 200 req/s: most of these 30 back-to-back calls hit an
    // empty bucket, yet every one succeeds — the client absorbed the
    // throttle instead of surfacing it
    for _ in 0..30 {
        api.jobs(&page(10, None)).unwrap();
    }
    let usage = api.tenant_usage().unwrap();
    assert!(usage.requests >= 30);
    assert!(usage.throttled >= 1, "rapid fire must have tripped the limiter");
    assert_eq!(usage.rejected, 0);

    // burn the remaining lifetime quota: unlike a throttle, the hard
    // 429 is not retryable and surfaces on both clients
    let mut exhausted = None;
    for _ in 0..2 * QUOTA {
        match api.jobs(&page(10, None)) {
            Ok(_) => {}
            Err(e) => {
                exhausted = Some(e);
                break;
            }
        }
    }
    let err = exhausted.expect("request quota must exhaust");
    assert_eq!(err.status(), 429);
    assert!(err.to_string().contains("quota"), "{err}");

    // observability survives exhaustion
    let usage = api.tenant_usage().unwrap();
    assert!(usage.requests <= QUOTA, "nothing admitted past the cap");
    assert!(usage.rejected >= 1);
    assert!(usage.api_cost > 0.0, "admitted traffic still bills");
}

#[test]
fn in_process_client_absorbs_throttles_until_quota() {
    let acai = Arc::new(Acai::boot(throttled_config()).unwrap());
    let root = acai.credentials.root_token().to_string();
    let (_p, token) = acai.credentials.create_project(&root, "capped", "alice").unwrap();
    let client = Client::connect(acai.clone(), &token).unwrap();
    throttled_suite(&client);
}

#[test]
fn remote_client_absorbs_throttles_until_quota() {
    let acai = Arc::new(Acai::boot(throttled_config()).unwrap());
    let root = acai.credentials.root_token().to_string();
    let server = Server::serve(0, make_handler(acai.clone())).unwrap();
    let (_p, remote) =
        RemoteClient::create_project(server.addr(), &root, "capped", "alice").unwrap();
    throttled_suite(&remote);
}

#[test]
fn rate_limited_request_carries_the_envelope_and_retry_after() {
    // burst 1 at 0.5 req/s: the second raw request must bounce, and
    // this test reads the wire bytes the SDK retry loop normally hides
    let config = PlatformConfig {
        tenant: TenantConfig {
            rate_limit_rps: 0.5,
            rate_limit_burst: 1.0,
            request_quota: None,
            byte_quota: None,
        },
        ..PlatformConfig::default()
    };
    let acai = Arc::new(Acai::boot(config).unwrap());
    let root = acai.credentials.root_token().to_string();
    let (_p, token) = acai.credentials.create_project(&root, "raw", "alice").unwrap();
    let server = Server::serve(0, make_handler(acai.clone())).unwrap();

    let mut conn = HttpConn::connect(server.addr()).unwrap();
    let headers = [("x-acai-token", token.as_str())];
    // the first request drains the one-token bucket...
    assert_eq!(conn.request("GET", "/v1/jobs?limit=10", &headers, b"").unwrap().status, 200);
    // ...and the second answers 429 through the uniform envelope with
    // the exact refill wait in `retry-after`
    let resp = conn.request("GET", "/v1/jobs?limit=10", &headers, b"").unwrap();
    assert_eq!(resp.status, 429);
    let wait: f64 = resp
        .header("retry-after")
        .expect("throttles are retryable")
        .parse()
        .unwrap();
    assert!(wait > 1.0 && wait <= 2.0, "one token at 0.5 rps refills in ~2s, got {wait}");
    let rid = resp.header("x-request-id").expect("every response is stamped").to_string();
    let v = acai::json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
    let err = v.get("error").expect("uniform envelope");
    assert_eq!(err.get("code").and_then(Json::as_str), Some("exhausted"));
    assert!(err.get("message").and_then(Json::as_str).unwrap().contains("rate limit"));
    assert_eq!(err.get("request_id").and_then(Json::as_str), Some(rid.as_str()));

    // the bounce was counted as throttled, not admitted
    let u = acai.tenants.usage(_p);
    assert_eq!(u.throttled, 1);
    assert_eq!(u.requests, 1);
}

/// ISSUE-5 acceptance: the content-addressed data plane end to end.
/// A slow two-node pool makes transfer time dominate: the first (cold)
/// job pays the full dataset over the wire; the second job lands on
/// the warm node via the locality tie-break and transfers nothing.
/// Returns the bit patterns of both runtimes and costs so two runs
/// (and the two clients) can be compared for exact determinism.
fn locality_outcome(api: &dyn AcaiApi) -> (u64, u64, u64, u64) {
    // 1 MB/s NIC: a ~96 KiB dataset costs ~0.1s of transfer, far above
    // the SimClock's microsecond resolution
    api.put_cluster_pool(&PoolSpec {
        name: "edge".into(),
        vcpus: 4.0,
        mem_mb: 8192,
        bandwidth_mbps: 1.0,
        price_multiplier: 1.0,
        min_nodes: 2,
        max_nodes: 2,
        preemption_mean_secs: 0.0,
    })
    .unwrap();

    // a deterministic ~96 KiB dataset (two 64 KiB chunks, one partial)
    let v1: Vec<u8> = (0..96 * 1024u32).map(|i| (i % 251) as u8).collect();
    api.upload(&[("/ds/shard.bin", &v1)]).unwrap();
    api.make_file_set("ds", &["/ds/shard.bin"]).unwrap();

    // dedup acceptance: v2 appends 16 KiB to v1 — the shared 64 KiB
    // prefix chunk is stored once, so the delta is far below 2x
    let before = api.data_metrics().unwrap();
    let mut v2 = v1.clone();
    v2.extend((0..16 * 1024u32).map(|i| (i % 13) as u8));
    api.upload(&[("/ds/shard.bin", &v2)]).unwrap();
    let after = api.data_metrics().unwrap();
    let logical_delta = after.logical_bytes - before.logical_bytes;
    let stored_delta = after.stored_bytes - before.stored_bytes;
    assert_eq!(logical_delta, v2.len() as u64);
    assert!(
        2 * stored_delta < logical_delta,
        "re-upload sharing >=90% must store far less than it ingests: \
         stored {stored_delta} vs logical {logical_delta}"
    );
    // total stored across both versions stays under 2x one version
    assert!(
        after.stored_bytes < 2 * v1.len() as u64 + before.stored_bytes,
        "stored {} must undercut 2x the logical dataset {}",
        after.stored_bytes,
        v1.len()
    );
    assert!(after.dedup_ratio() > 1.0);

    // cold run: every input chunk crosses the 1 MB/s wire
    let mut cold_req = job_request("cold", "ds:1", "cold-out");
    cold_req.pool = Some("edge".into());
    let cold = api.await_job(api.submit_job(&cold_req).unwrap()).unwrap();
    assert_eq!(cold.state, "finished");
    let cold_transfer = cold.transfer_secs.expect("cold run must pay transfer");
    assert!(cold_transfer > 0.05, "1 MB/s x 96 KiB ~ 0.1s, saw {cold_transfer}");

    // warm replay: same input — placement must pick the node whose
    // cache already holds the chunks, and transfer exactly nothing
    let mut warm_req = job_request("warm", "ds:1", "warm-out");
    warm_req.pool = Some("edge".into());
    let warm = api.await_job(api.submit_job(&warm_req).unwrap()).unwrap();
    assert_eq!(warm.state, "finished");
    assert_eq!(warm.transfer_secs, None, "warm replay transfers nothing");
    assert!(
        warm.runtime_secs.unwrap() < cold.runtime_secs.unwrap(),
        "warm {} must finish strictly earlier than cold {}",
        warm.runtime_secs.unwrap(),
        cold.runtime_secs.unwrap()
    );
    assert!(
        warm.cost.unwrap() < cold.cost.unwrap(),
        "warm {} must bill strictly less than cold {}",
        warm.cost.unwrap(),
        cold.cost.unwrap()
    );

    // the counters saw it all: one cold pull, one full cache hit
    let dm = api.data_metrics().unwrap();
    assert_eq!(dm.cold_transfer_bytes, v1.len() as u64);
    assert_eq!(dm.cache_hit_bytes, v1.len() as u64);
    assert!(dm.transfer_secs > 0.05);
    // node listing exposes the warm cache
    let nodes = api.cluster_nodes().unwrap();
    let warm_nodes = nodes.iter().filter(|n| n.cached_bytes > 0).count();
    assert_eq!(warm_nodes, 1, "exactly one edge node holds the dataset");

    (
        cold.runtime_secs.unwrap().to_bits(),
        cold.cost.unwrap().to_bits(),
        warm.runtime_secs.unwrap().to_bits(),
        warm.cost.unwrap().to_bits(),
    )
}

// ---------------------------------------------------------------------------
// Datalake time travel: commits, branches, diffs, pinned replay
// ---------------------------------------------------------------------------

/// Parse the byte count out of the agent's download log line
/// (`agent: input fileset NAME (N bytes) downloaded; ...`).
fn downloaded_bytes(api: &dyn AcaiApi, id: JobId) -> u64 {
    let chunk = api.job_logs(id, 0).unwrap();
    let line = chunk
        .lines
        .iter()
        .find(|l| l.contains("input fileset"))
        .expect("agent download line");
    line.split('(')
        .nth(1)
        .and_then(|rest| rest.split(' ').next())
        .unwrap()
        .parse()
        .unwrap()
}

/// The time-travel acceptance flow: snapshot → mutate → exact chunk
/// diff → commit-pinned reads vs live reads → GC survival → rollback
/// → pinned replay.  Returns every float observable as raw bits so the
/// two client runs can be compared for bit-identical replay.
fn time_travel_outcome(api: &dyn AcaiApi) -> (u64, u64, u64, u64, u64, u64) {
    // ---- v1 lake state, snapshotted ----
    api.upload(&[
        ("/tt/a.bin", b"alpha-original"), // 14 bytes
        ("/tt/b.bin", b"bravo-stable"),   // 12 bytes
        ("/tt/c.bin", b"charlie-doomed"), // 14 bytes
    ])
    .unwrap();
    api.make_file_set("tt-corpus", &["/tt/a.bin", "/tt/b.bin", "/tt/c.bin"]).unwrap();
    let c1 = api.create_commit("v1 of the corpus").unwrap();
    assert_eq!(c1.files, 3);
    assert_eq!(c1.bytes, 40);
    assert_eq!(api.get_commit(&c1.id).unwrap().message, "v1 of the corpus");
    assert_eq!(api.commits().unwrap().len(), 1);
    assert_eq!(api.get_commit("commit-999").unwrap_err().status(), 404);

    // a branch names the snapshot; the ref protects it from deletion
    let release = api.create_branch("release", &c1.id).unwrap();
    assert_eq!(release.commit, c1.id);
    assert_eq!(api.get_branch("release").unwrap().commit, c1.id);
    assert_eq!(api.branches().unwrap().len(), 1);
    assert_eq!(api.create_branch("release", &c1.id).unwrap_err().status(), 409);
    assert_eq!(api.create_branch("bad/name", &c1.id).unwrap_err().status(), 400);
    assert_eq!(api.delete_commit(&c1.id).unwrap_err().status(), 409);

    // a dangling pin fails at submit, never at launch
    let mut dangling = job_request("tt-dangling", "tt-corpus:1", "tt-x");
    dangling.data_commit = Some("commit-999".into());
    assert_eq!(api.submit_job(&dangling).unwrap_err().status(), 404);

    // ---- mutate the lake past the snapshot ----
    api.upload(&[("/tt/a.bin", b"alpha-rewritten-and-longer")]).unwrap(); // 26 bytes
    api.delete_file("/tt/c.bin", 1).unwrap();
    assert_eq!(api.fetch("/tt/c.bin", Some(1)).unwrap_err().status(), 404);
    api.upload(&[("/tt/d.bin", b"delta-new")]).unwrap(); // 9 bytes
    api.make_file_set("tt-corpus", &["/tt/a.bin", "/tt/b.bin", "/tt/d.bin"]).unwrap();
    let c2 = api.create_commit("v2 of the corpus").unwrap();
    assert_eq!(c2.files, 3);
    assert_eq!(c2.bytes, 26 + 12 + 9);
    assert_eq!(api.commits().unwrap().len(), 2);

    // ---- chunk-level diff: exact per-file deltas ----
    let diff = api.diff_commits(&c1.id, &c2.id).unwrap();
    assert_eq!(diff.added.len(), 1);
    assert_eq!(diff.added[0].path, "/tt/d.bin");
    assert_eq!(diff.added[0].bytes, 9);
    assert_eq!(diff.removed.len(), 1);
    assert_eq!(diff.removed[0].path, "/tt/c.bin");
    assert_eq!(diff.removed[0].bytes, 14);
    assert_eq!(diff.changed.len(), 1);
    let ch = &diff.changed[0];
    assert_eq!(ch.path, "/tt/a.bin");
    assert_eq!(
        (ch.bytes_added, ch.bytes_removed, ch.chunks_added, ch.chunks_removed),
        (26, 14, 1, 1)
    );
    assert_eq!(ch.changed_bytes(), 40);
    // identity: a commit never differs from itself
    assert!(api.diff_commits(&c1.id, &c1.id).unwrap().is_empty());
    // symmetry: swapping sides swaps added/removed and the byte columns
    let back = api.diff_commits(&c2.id, &c1.id).unwrap();
    assert_eq!(back.added[0].path, "/tt/c.bin");
    assert_eq!(back.removed[0].path, "/tt/d.bin");
    assert_eq!((back.changed[0].bytes_added, back.changed[0].bytes_removed), (14, 26));

    // ---- a sweep pinned to the snapshot runs against deleted data ----
    let mut pinned_spec = experiment_spec(
        "tt-pinned",
        "python train_mnist.py --epoch {1,2,3}",
        "tt-corpus:1",
    );
    pinned_spec.data_commit = Some(c1.id.clone());
    let exp = api.create_experiment(&pinned_spec).unwrap();
    let done = api.await_experiment(exp.id).unwrap();
    assert_eq!(done.state, "completed");
    assert_eq!(done.finished, 3);
    let pinned_best = api.best_trial(exp.id, "training_loss", MetricMode::Min).unwrap();
    let pinned_bits = pinned_best.metric("training_loss").unwrap().to_bits();
    // a pinned job resolves /tt/c.bin's deleted bytes through the commit
    let mut pinned_req = job_request("tt-pinned-job", "tt-corpus:1", "tt-pj");
    pinned_req.data_commit = Some(c1.id.clone());
    let pj = api.submit_job(&pinned_req).unwrap();
    assert_eq!(api.await_job(pj).unwrap().state, "finished");
    let pinned_input = downloaded_bytes(api, pj);
    assert_eq!(pinned_input, 40, "pinned job reads the snapshot bytes");
    // the same fileset version UNPINNED cannot launch: the live table
    // no longer holds v1 of /tt/c.bin
    let dead = api
        .submit_job(&job_request("tt-dead", "tt-corpus:1", "tt-dead-out"))
        .unwrap();
    assert_ne!(api.await_job(dead).unwrap().state, "finished");
    // an unpinned job on the live fileset sees the new data
    let live = api
        .submit_job(&job_request("tt-live", "tt-corpus", "tt-live-out"))
        .unwrap();
    assert_eq!(api.await_job(live).unwrap().state, "finished");
    let live_input = downloaded_bytes(api, live);
    assert_eq!(live_input, 47, "unpinned job reads the mutated lake");
    // ...and so does an unpinned sweep
    let live_exp = api
        .create_experiment(&experiment_spec(
            "tt-live-sweep",
            "python train_mnist.py --epoch {1,2,3}",
            "tt-corpus",
        ))
        .unwrap();
    assert_eq!(api.await_experiment(live_exp.id).unwrap().state, "completed");
    let live_best = api.best_trial(live_exp.id, "training_loss", MetricMode::Min).unwrap();
    let live_bits = live_best.metric("training_loss").unwrap().to_bits();

    // ---- a full GC sweep spares commit-pinned chunks ----
    let gc = api.gc_sweep().unwrap();
    assert_eq!(gc.unreferenced_files, 0, "every live version is pinned");
    assert_eq!(gc.reclaimed_chunks, 0, "every chunk is held by a row or a commit");
    let mut post_gc_req = job_request("tt-post-gc", "tt-corpus:1", "tt-gc-out");
    post_gc_req.data_commit = Some(c1.id.clone());
    let post_gc = api.submit_job(&post_gc_req).unwrap();
    assert_eq!(api.await_job(post_gc).unwrap().state, "finished");
    assert_eq!(downloaded_bytes(api, post_gc), 40, "pinned bytes survive GC");

    // ---- rollback: the branch restores the file table in place ----
    let report = api.rollback_branch("release").unwrap();
    assert_eq!(report.commit, c1.id);
    assert_eq!(report.restored, 1, "/tt/c.bin re-written from the snapshot");
    // /tt/a.bin moves back onto v1; /tt/c.bin's pointer is recreated
    assert_eq!(report.repointed, 2);
    // /tt/d.bin and the jobs' /model outputs were born after the commit
    assert_eq!(report.removed, 2);
    assert_eq!(api.fetch("/tt/a.bin", None).unwrap(), b"alpha-original");
    assert_eq!(api.fetch("/tt/c.bin", None).unwrap(), b"charlie-doomed");
    assert_eq!(api.fetch("/tt/d.bin", None).unwrap_err().status(), 404);
    // history above the snapshot survives as explicit versions
    assert_eq!(api.fetch("/tt/a.bin", Some(2)).unwrap(), b"alpha-rewritten-and-longer");

    // ---- the pinned sweep replays against the rolled-back lake ----
    let mut replay_spec = experiment_spec(
        "tt-replay",
        "python train_mnist.py --epoch {1,2,3}",
        "tt-corpus:1",
    );
    replay_spec.data_commit = Some(c1.id.clone());
    let replay = api.create_experiment(&replay_spec).unwrap();
    assert_eq!(api.await_experiment(replay.id).unwrap().state, "completed");
    let replay_best = api.best_trial(replay.id, "training_loss", MetricMode::Min).unwrap();
    let replay_bits = replay_best.metric("training_loss").unwrap().to_bits();
    let mut replay_req = job_request("tt-replay-job", "tt-corpus:1", "tt-rj");
    replay_req.data_commit = Some(c1.id.clone());
    let replay_job = api.submit_job(&replay_req).unwrap();
    assert_eq!(api.await_job(replay_job).unwrap().state, "finished");
    let replay_input = downloaded_bytes(api, replay_job);
    assert_eq!(replay_input, pinned_input, "replay reads identical snapshot bytes");

    // branch lifecycle: drop the ref, then the commit becomes deletable
    api.delete_branch("release").unwrap();
    assert_eq!(api.get_branch("release").unwrap_err().status(), 404);
    assert_eq!(api.delete_branch("release").unwrap_err().status(), 404);
    api.delete_commit(&c2.id).unwrap();
    assert_eq!(api.commits().unwrap().len(), 1);

    (pinned_bits, live_bits, replay_bits, pinned_input, live_input, replay_input)
}

#[test]
fn time_travel_acceptance_in_process() {
    let acai = Arc::new(Acai::boot_default());
    let root = acai.credentials.root_token().to_string();
    let (_p, token) = acai.credentials.create_project(&root, "tt", "alice").unwrap();
    let client = Client::connect(acai, &token).unwrap();
    time_travel_outcome(&client);
}

#[test]
fn time_travel_replay_is_bit_identical_across_clients() {
    // in-process client on a fresh platform
    let acai = Arc::new(Acai::boot_default());
    let root = acai.credentials.root_token().to_string();
    let (_p, token) = acai.credentials.create_project(&root, "tt", "alice").unwrap();
    let client = Client::connect(acai, &token).unwrap();
    let local = time_travel_outcome(&client);

    // remote client on its own fresh platform behind real HTTP: the
    // commit pins the same bytes, so the whole timeline — best-trial
    // metrics included — replays bit-for-bit
    let acai2 = Arc::new(Acai::boot_default());
    let root2 = acai2.credentials.root_token().to_string();
    let server = Server::serve(0, make_handler(acai2)).unwrap();
    let (_proj, remote) =
        RemoteClient::create_project(server.addr(), &root2, "tt", "alice").unwrap();
    assert_eq!(local, time_travel_outcome(&remote), "wire and in-process must agree bitwise");
}

#[test]
fn warm_cache_launch_is_cheaper_and_bit_identical_across_clients() {
    // in-process client on a fresh platform, twice (replay determinism)
    let in_process = || {
        let acai = Arc::new(Acai::boot_default());
        let root = acai.credentials.root_token().to_string();
        let (_p, token) = acai.credentials.create_project(&root, "loc", "alice").unwrap();
        let client = Client::connect(acai, &token).unwrap();
        locality_outcome(&client)
    };
    let a = in_process();
    let b = in_process();
    assert_eq!(a, b, "same seed must replay the same transfer timeline");

    // and the wire changes nothing
    let acai = Arc::new(Acai::boot_default());
    let root = acai.credentials.root_token().to_string();
    let server = Server::serve(0, make_handler(acai)).unwrap();
    let (_proj, remote) =
        RemoteClient::create_project(server.addr(), &root, "loc", "alice").unwrap();
    assert_eq!(a, locality_outcome(&remote), "wire and in-process must agree bitwise");
}

// ---------------------------------------------------------------------------
// Observability: request-id propagation, job-lifecycle traces, metrics
// ---------------------------------------------------------------------------

#[test]
fn client_supplied_request_ids_are_honored_and_traceable() {
    let acai = Arc::new(Acai::boot_default());
    let root = acai.credentials.root_token().to_string();
    let (_p, token) = acai.credentials.create_project(&root, "rid", "alice").unwrap();
    let server = Server::serve(0, make_handler(acai.clone())).unwrap();

    // a client-minted id is echoed verbatim on the response...
    let mut conn = HttpConn::connect(server.addr()).unwrap();
    let headers = [("x-acai-token", token.as_str()), ("x-request-id", "trace-me-42")];
    let resp = conn.request("GET", "/v1/jobs?limit=10", &headers, b"").unwrap();
    assert_eq!(resp.status, 200);
    assert_eq!(resp.header("x-request-id"), Some("trace-me-42"));

    // ...and keys the request's span timeline
    let remote = RemoteClient::connect(server.addr(), token.as_str()).unwrap();
    let trace = remote.request_trace("trace-me-42").unwrap();
    assert_eq!(trace.request_id, "trace-me-42");
    assert_eq!(trace.events.first().unwrap().name, "request");
    let response = trace.events.last().unwrap();
    assert_eq!(response.name, "response");
    assert_eq!(response.field("status").and_then(Json::as_u64), Some(200));
    assert_eq!(response.field("route").and_then(Json::as_str), Some("GET /v1/jobs"));

    // a request without one still gets a server-minted id
    let bare = [("x-acai-token", token.as_str())];
    let resp = conn.request("GET", "/v1/jobs?limit=10", &bare, b"").unwrap();
    let minted = resp.header("x-request-id").expect("every response is stamped");
    assert!(minted.starts_with("req-"), "got {minted}");

    // oversized client ids are replaced, never echoed back
    let big = "x".repeat(200);
    let headers = [("x-acai-token", token.as_str()), ("x-request-id", big.as_str())];
    let resp = conn.request("GET", "/v1/jobs?limit=10", &headers, b"").unwrap();
    assert_ne!(resp.header("x-request-id"), Some(big.as_str()));

    // the SDK mints an id per call; the last one resolves to its trace
    remote.jobs(&page(10, None)).unwrap();
    let rid = remote.last_request_id();
    assert!(rid.starts_with("rc"), "SDK ids are client-minted, got {rid}");
    let trace = remote.request_trace(&rid).unwrap();
    assert_eq!(trace.request_id, rid);
    assert!(trace.events.iter().any(|e| e.name == "response"));

    // another project cannot read this project's request traces
    let (_p2, token2) = acai.credentials.create_project(&root, "rid2", "bob").unwrap();
    let other = RemoteClient::connect(server.addr(), token2.as_str()).unwrap();
    assert_eq!(other.request_trace("trace-me-42").unwrap_err().status(), 404);
}

/// Assert `milestones` appear in the trace in order; other events
/// (monitor stage mirrors, container events) may interleave freely.
fn assert_milestones(trace: &JobTrace, milestones: &[&str]) {
    let names: Vec<&str> = trace.events.iter().map(|e| e.name.as_str()).collect();
    let mut pos = 0usize;
    for m in milestones {
        match names[pos..].iter().position(|n| n == m) {
            Some(i) => pos += i + 1,
            None => panic!("milestone {m:?} missing after index {pos} in {names:?}"),
        }
    }
}

/// ISSUE-9 acceptance: a gang job evicted by a high-priority arrival
/// exposes its complete lifecycle — queue → gang placement → transfer
/// → run → preempt → resume → re-placement → re-run → complete —
/// through `GET /v1/trace/jobs/{id}`, with phase durations that
/// account for the billed runtime.  Returns the canonical JSON of both
/// timelines so runs and clients can be compared bit-for-bit.
fn preempted_gang_timeline(api: &dyn AcaiApi, acai: &Acai) -> (String, String) {
    // a deterministic 64 KiB dataset so the cold transfer is visible
    let payload: Vec<u8> = (0..64 * 1024u32).map(|i| (i % 241) as u8).collect();
    api.upload(&[("/gang/shard.bin", &payload)]).unwrap();
    api.make_file_set("gang-data", &["/gang/shard.bin"]).unwrap();

    // freeze the event loop: both submissions land at virtual time 0,
    // so placement and eviction order is a pure function of the seed
    let (low, high);
    {
        let _drive = acai.engine.drive_guard();
        // the gang fills the single 8-vcpu node...
        let mut low_req = job_request("gang-low", "gang-data", "low-out");
        low_req.resources = ResourceConfig::new(4.0, 4096);
        low_req.gang = 2;
        low_req.priority = acai::engine::Priority::Low;
        low = api.submit_job(&low_req).unwrap();
        // ...and the high-priority arrival can only run by evicting it
        let mut high_req = job_request("bully", "gang-data", "high-out");
        high_req.resources = ResourceConfig::new(8.0, 8192);
        high_req.priority = acai::engine::Priority::High;
        high = api.submit_job(&high_req).unwrap();
    }
    assert_eq!(api.await_job(low).unwrap().state, "finished");
    assert_eq!(api.await_job(high).unwrap().state, "finished");

    let low_trace = api.job_trace(low).unwrap();
    assert_eq!(low_trace.state, "finished");
    assert_eq!(low_trace.preemptions, 1, "the gang must have been evicted once");
    assert_milestones(
        &low_trace,
        &[
            "enqueue", "placement", "transfer", "run", "preempt", "resume", "placement",
            "run", "complete",
        ],
    );
    let placement = low_trace.events.iter().find(|e| e.name == "placement").unwrap();
    assert_eq!(placement.field("gang").and_then(Json::as_u64), Some(2));
    let preempt = low_trace.events.iter().find(|e| e.name == "preempt").unwrap();
    assert!(
        preempt.field("cause").and_then(Json::as_str).unwrap().contains("evicted"),
        "priority eviction must name its cause"
    );
    // the eviction cost the job real queue time behind the bully, and
    // the phase durations account for every billed second
    assert!(low_trace.queue_wait > 0.0, "resumed gang waited behind the bully");
    // the cold 64 KiB load is visible on the first attempt's run event;
    // the transfer *phase* only counts time the attempt actually spent,
    // and this attempt was evicted the instant it launched
    let first_run = low_trace.events.iter().find(|e| e.name == "run").unwrap();
    assert!(
        first_run.field("transfer_secs").and_then(Json::as_f64).unwrap() > 0.0,
        "cold 64 KiB input transfer is visible"
    );
    let runtime = api.job_status(low).unwrap().runtime_secs.unwrap();
    let replayed = low_trace.transfer + low_trace.run + low_trace.rework;
    assert!(
        (replayed - runtime).abs() < 1e-6 * runtime.max(1.0),
        "phases {replayed} must account for runtime {runtime}"
    );
    // span ids are unique within the timeline
    let mut spans: Vec<&str> = low_trace.events.iter().map(|e| e.span.as_str()).collect();
    spans.sort_unstable();
    spans.dedup();
    assert_eq!(spans.len(), low_trace.events.len());

    // the beneficiary's timeline names its victim
    let high_trace = api.job_trace(high).unwrap();
    assert_eq!(high_trace.preemptions, 0);
    assert_milestones(&high_trace, &["enqueue", "eviction", "placement", "run", "complete"]);
    let eviction = high_trace.events.iter().find(|e| e.name == "eviction").unwrap();
    assert_eq!(
        eviction.field("victim").and_then(Json::as_str),
        Some(low.to_string().as_str())
    );

    (low_trace.to_json().encode(), high_trace.to_json().encode())
}

#[test]
fn preempted_gang_trace_is_complete_and_bit_identical_across_clients() {
    let contended = || PlatformConfig {
        cluster: ClusterConfig::fixed(NodeSpec::new(8.0, 8192), 1),
        ..PlatformConfig::default()
    };

    // in-process client on a fresh platform, twice (replay determinism)
    let in_process = || {
        let acai = Arc::new(Acai::boot(contended()).unwrap());
        let root = acai.credentials.root_token().to_string();
        let (_p, token) = acai.credentials.create_project(&root, "gang", "alice").unwrap();
        let client = Client::connect(acai.clone(), &token).unwrap();
        preempted_gang_timeline(&client, &acai)
    };
    let a = in_process();
    let b = in_process();
    assert_eq!(a, b, "same-seed replay must produce identical timelines");

    // and the wire changes nothing: span ids, timestamps, ordinals and
    // phase durations all replay bit-for-bit through real HTTP
    let acai = Arc::new(Acai::boot(contended()).unwrap());
    let root = acai.credentials.root_token().to_string();
    let server = Server::serve(0, make_handler(acai.clone())).unwrap();
    let (_proj, remote) =
        RemoteClient::create_project(server.addr(), &root, "gang", "alice").unwrap();
    assert_eq!(
        a,
        preempted_gang_timeline(&remote, &acai),
        "wire and in-process timelines must agree bitwise"
    );
}

/// Extract one sample value from the Prometheus text exposition.
fn prom_sample(text: &str, series: &str) -> f64 {
    text.lines()
        .find_map(|l| l.strip_prefix(series).and_then(|rest| rest.strip_prefix(' ')))
        .unwrap_or_else(|| panic!("series {series} missing from exposition"))
        .trim()
        .parse()
        .unwrap()
}

#[test]
fn metrics_json_and_prometheus_agree_under_a_contended_storm() {
    // one small node, two tenants: the second wave of jobs queues
    // behind the first, so the queue-wait histogram fills non-trivial
    // buckets (zero wait for wave one, a full job runtime for wave two)
    let config = PlatformConfig {
        cluster: ClusterConfig::fixed(NodeSpec::new(4.0, 8192), 1),
        ..PlatformConfig::default()
    };
    let acai = Arc::new(Acai::boot(config).unwrap());
    let root = acai.credentials.root_token().to_string();
    let server = Server::serve(0, make_handler(acai.clone())).unwrap();
    let (_pa, ta) = acai.credentials.create_project(&root, "storm-a", "alice").unwrap();
    let (_pb, tb) = acai.credentials.create_project(&root, "storm-b", "bob").unwrap();
    let a = RemoteClient::connect(server.addr(), ta.as_str()).unwrap();
    let b = RemoteClient::connect(server.addr(), tb.as_str()).unwrap();

    for client in [&a, &b] {
        client.upload(&[("/storm/corpus.bin", b"storm-bytes")]).unwrap();
        client.make_file_set("storm", &["/storm/corpus.bin"]).unwrap();
    }
    // submit the whole storm at virtual time 0 (the drive guard keeps
    // the background driver from draining wave one mid-submission)
    let mut jobs: Vec<(&RemoteClient, acai::ids::JobId)> = Vec::new();
    {
        let _drive = acai.engine.drive_guard();
        for i in 0..4 {
            for (client, tag) in [(&a, "a"), (&b, "b")] {
                let id = client
                    .submit_job(&job_request(
                        &format!("storm-{tag}-{i}"),
                        "storm",
                        &format!("{tag}{i}-out"),
                    ))
                    .unwrap();
                jobs.push((client, id));
            }
        }
    }
    for (client, id) in &jobs {
        assert_eq!(client.await_job(*id).unwrap().state, "finished");
    }

    // scrape both renderings of the shared registry
    let mut conn = HttpConn::connect(server.addr()).unwrap();
    let headers = [("x-acai-token", ta.as_str())];
    let resp = conn.request("GET", "/v1/metrics", &headers, b"").unwrap();
    assert_eq!(resp.status, 200);
    let v = acai::json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
    let rows = v
        .get("registry")
        .and_then(|r| r.get("metrics"))
        .and_then(Json::as_array)
        .expect("registry block in GET /v1/metrics");

    let resp = conn.request("GET", "/v1/metrics?format=prometheus", &headers, b"").unwrap();
    assert_eq!(resp.status, 200);
    assert!(resp.header("content-type").unwrap().starts_with("text/plain"));
    let text = String::from_utf8(resp.body.clone()).unwrap();

    // the queue-wait histogram saw all 8 placements and spread them
    // across at least two buckets (the storm was real)
    let qw = rows
        .iter()
        .find(|m| m.get("name").and_then(Json::as_str) == Some("acai_job_queue_wait_seconds"))
        .expect("queue-wait histogram in the registry block");
    let count = qw.get("count").and_then(Json::as_u64).unwrap();
    assert_eq!(count, 8);
    let sum = qw.get("sum").and_then(Json::as_f64).unwrap();
    assert!(sum > 0.0, "wave two waited a full job runtime");
    let buckets = qw.get("buckets").and_then(Json::as_array).unwrap();
    let nonzero = buckets
        .iter()
        .filter(|b| b.get("count").and_then(Json::as_u64).unwrap() > 0)
        .count();
    assert!(nonzero >= 2, "contended storm must spread queue waits across buckets");

    // the Prometheus exposition reports the exact same values: count,
    // sum, and every cumulative bucket replays the JSON bucket counts
    assert_eq!(prom_sample(&text, "acai_job_queue_wait_seconds_count"), count as f64);
    assert!((prom_sample(&text, "acai_job_queue_wait_seconds_sum") - sum).abs() < 1e-9);
    let mut cum = 0u64;
    for bucket in buckets {
        cum += bucket.get("count").and_then(Json::as_u64).unwrap();
        let le = match bucket.get("le").unwrap() {
            Json::Str(s) => s.clone(),
            other => format!("{}", other.as_f64().unwrap()),
        };
        let series = format!("acai_job_queue_wait_seconds_bucket{{le=\"{le}\"}}");
        assert_eq!(prom_sample(&text, &series), cum as f64, "bucket le={le}");
    }
    assert_eq!(cum, count, "buckets must partition every observation");

    // counters agree across renderings too (engine series are stable
    // between the two scrapes: every job is terminal)
    for name in ["acai_jobs_submitted_total", "acai_jobs_finished_total"] {
        let json_value = rows
            .iter()
            .find(|m| m.get("name").and_then(Json::as_str) == Some(name))
            .and_then(|m| m.get("value"))
            .and_then(Json::as_u64)
            .unwrap_or_else(|| panic!("{name} in the registry block"));
        assert_eq!(json_value, 8);
        assert_eq!(prom_sample(&text, name), 8.0);
    }
}
