//! Data-lake integration: storage + file sets + metadata + provenance
//! working together across services (paper §3.2, §4.4, §4.5).

use acai::datalake::metadata::ArtifactKind;
use acai::datalake::SessionState;
use acai::docstore::Clause;
use acai::ids::ProjectId;
use acai::json::Json;
use acai::Acai;

const P: ProjectId = ProjectId(1);

fn lake() -> Acai {
    Acai::boot_default()
}

#[test]
fn upload_fileset_materialize_round_trip() {
    let acai = lake();
    let dl = &acai.datalake;
    dl.storage
        .upload(
            P,
            &[
                ("/data/train.json", b"train-data"),
                ("/data/dev.json", b"dev-data"),
            ],
        )
        .unwrap();
    dl.filesets
        .create(P, "HotpotQA", &["/data/train.json", "/data/dev.json"], "alice")
        .unwrap();
    let files = dl.filesets.materialize(P, "HotpotQA", None).unwrap();
    assert_eq!(files.len(), 2);
    let train = files.iter().find(|(p, _)| p == "/data/train.json").unwrap();
    assert_eq!(train.1, b"train-data");
}

#[test]
fn version_pinning_survives_many_updates() {
    let acai = lake();
    let dl = &acai.datalake;
    for i in 0..10u32 {
        dl.storage
            .upload(P, &[("/f", format!("content-{i}").as_bytes())])
            .unwrap();
        if i == 4 {
            dl.filesets.create(P, "snapshot", &["/f"], "alice").unwrap();
        }
    }
    // snapshot still points at version 5 (uploads are 1-based)
    let bytes = dl.filesets.materialize(P, "snapshot", None).unwrap();
    assert_eq!(bytes[0].1, b"content-4");
    assert_eq!(dl.storage.versions(P, "/f").len(), 10);
}

#[test]
fn merge_update_subset_chain_builds_full_provenance() {
    let acai = lake();
    let dl = &acai.datalake;
    dl.storage
        .upload(
            P,
            &[
                ("/data/a.json", b"a"),
                ("/data/b.json", b"b"),
                ("/validation/v.json", b"v"),
            ],
        )
        .unwrap();
    dl.filesets.create(P, "A", &["/data/a.json"], "alice").unwrap();
    dl.filesets.create(P, "B", &["/data/b.json"], "alice").unwrap();
    dl.filesets.create(P, "Merged", &["/@A", "/@B"], "alice").unwrap();
    dl.filesets
        .create(P, "Merged", &["/@Merged", "/validation/v.json"], "alice")
        .unwrap();
    dl.filesets
        .create(P, "Val", &["/validation/@Merged:2"], "alice")
        .unwrap();

    // lineage of Val: Merged:2 -> {Merged:1, v.json} -> {A:1, B:1}
    let ancestors = dl.provenance.ancestors(P, "Val", 1);
    for expected in ["Merged:2", "Merged:1", "A:1", "B:1"] {
        assert!(ancestors.contains(&expected.to_string()), "{ancestors:?}");
    }
    // and metadata exists for every file-set version
    for id in ["A:1", "B:1", "Merged:1", "Merged:2", "Val:1"] {
        assert!(
            dl.metadata.get(P, ArtifactKind::FileSet, id).is_some(),
            "{id}"
        );
    }
}

#[test]
fn metadata_queries_cross_reference_provenance() {
    let acai = lake();
    let dl = &acai.datalake;
    dl.storage.upload(P, &[("/m", b"x")]).unwrap();
    dl.filesets.create(P, "S", &["/m"], "john").unwrap();
    dl.metadata.tag(
        P,
        ArtifactKind::FileSet,
        "S:1",
        &[
            ("model".into(), Json::from("BERT")),
            ("precision".into(), Json::from(0.8)),
        ],
    );
    let hits = dl
        .metadata
        .query(
            P,
            ArtifactKind::FileSet,
            &[Clause::eq("model", "BERT"), Clause::gte("precision", 0.5)],
        )
        .unwrap();
    assert_eq!(hits.len(), 1);
    let (id, _) = &hits[0];
    // the hit is a provenance node we can trace from
    assert_eq!(id, "S:1");
    assert!(dl.provenance.backward(P, "S", 1).is_empty()); // no upstream
}

#[test]
fn concurrent_uploads_get_distinct_sequential_versions() {
    let acai = lake();
    let storage = acai.datalake.storage.clone();
    let mut handles = vec![];
    for _ in 0..8 {
        let s = storage.clone();
        handles.push(std::thread::spawn(move || {
            for _ in 0..10 {
                s.upload(P, &[("/contended", b"x")]).unwrap();
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let versions = storage.versions(P, "/contended");
    assert_eq!(versions.len(), 80);
    // dense 1..=80, no gaps, no duplicates
    assert_eq!(versions, (1..=80).collect::<Vec<u32>>());
}

#[test]
fn abandoned_session_does_not_block_future_versions() {
    let acai = lake();
    let dl = &acai.datalake;
    dl.storage.upload(P, &[("/f", b"v1")]).unwrap();
    // start a session and walk away (no uploads)
    let (id, _grants) = dl.storage.start_session(P, &["/f"]).unwrap();
    assert!(matches!(
        dl.storage.poll_session(id).unwrap(),
        SessionState::Pending { .. }
    ));
    // other clients continue unimpeded
    let v = dl.storage.upload(P, &[("/f", b"v2")]).unwrap();
    assert_eq!(v[0].1, 2);
    dl.storage.abort_session(id).unwrap();
    let v = dl.storage.upload(P, &[("/f", b"v3")]).unwrap();
    assert_eq!(v[0].1, 3);
}

#[test]
fn fileset_spec_language_full_tour() {
    let acai = lake();
    let dl = &acai.datalake;
    dl.storage
        .upload(P, &[("/d/x", b"x1"), ("/d/y", b"y1"), ("/e/z", b"z1")])
        .unwrap();
    dl.storage.upload(P, &[("/d/x", b"x2")]).unwrap();
    dl.filesets
        .create(P, "Set", &["/d/x#1", "/d/y", "/e/z"], "u")
        .unwrap();

    // exact-version spec
    let r = dl.filesets.resolve(P, &["/d/x#1"]).unwrap();
    assert_eq!(r.entries, vec![("/d/x".to_string(), 1)]);
    // paper's space-suffix version spec
    let r = dl.filesets.resolve(P, &["/d/x 2"]).unwrap();
    assert_eq!(r.entries, vec![("/d/x".to_string(), 2)]);
    // file-at-set spec
    let r = dl.filesets.resolve(P, &["/d/x@Set"]).unwrap();
    assert_eq!(r.entries, vec![("/d/x".to_string(), 1)]);
    // directory filter
    let r = dl.filesets.resolve(P, &["/d/@Set:1"]).unwrap();
    assert_eq!(r.entries.len(), 2);
    // whole set
    let r = dl.filesets.resolve(P, &["/@Set"]).unwrap();
    assert_eq!(r.entries.len(), 3);
}
