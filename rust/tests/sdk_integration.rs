//! SDK integration: the full user journey of paper §3.4 through the
//! token-scoped client — the workflow the usability study times.

use std::sync::Arc;

use acai::autoprovision::Objective;
use acai::cluster::ResourceConfig;
use acai::datalake::metadata::ArtifactKind;
use acai::docstore::Clause;
use acai::engine::JobState;
use acai::json::Json;
use acai::sdk::{Client, JobRequest};
use acai::Acai;

fn client() -> (Arc<Acai>, Client) {
    let acai = Arc::new(Acai::boot_default());
    let root = acai.credentials.root_token().to_string();
    let (_p, token) = acai
        .credentials
        .create_project(&root, "nlp", "alice")
        .unwrap();
    let client = Client::connect(acai.clone(), &token).unwrap();
    (acai, client)
}

#[test]
fn complete_user_journey() {
    let (_acai, client) = client();

    // 1. upload data + build a file set
    client
        .upload_files(&[("/data/train.bin", b"train"), ("/data/dev.bin", b"dev")])
        .unwrap();
    client.create_file_set("corpus", &["/data/train.bin", "/data/dev.bin"]).unwrap();

    // 2. run a training job
    let job = client
        .submit(JobRequest {
            name: "train-mlp".into(),
            command: "python train_mnist.py --epoch 5".into(),
            input_fileset: "corpus".into(),
            output_fileset: "model".into(),
            resources: ResourceConfig::new(2.0, 2048),
            pool: None,
            data_commit: None,
            priority: acai::engine::Priority::Normal,
            gang: 1,
        })
        .unwrap();
    client.wait_all();
    let record = client.job(job).unwrap();
    assert_eq!(record.state, JobState::Finished);

    // 3. logs were captured, auto-tags applied
    let logs = client.logs(job);
    assert!(logs.iter().any(|l| l.contains("training_loss")));

    // 4. find the experiment by metadata
    let hits = client
        .query(ArtifactKind::Job, &[Clause::eq("name", "train-mlp")])
        .unwrap();
    assert_eq!(hits.len(), 1);
    assert_eq!(hits[0].0, job.to_string());

    // 5. trace provenance from the model back to the corpus
    let back = client.trace_backward("model", 1);
    assert_eq!(back[0].from, "corpus:1");
    let lineage = client.lineage("model", 1);
    assert!(lineage.contains(&"corpus:1".to_string()));

    // 6. retrieve the exact model bytes the job produced
    let model = client.download("/model/mlp.bin", None).unwrap();
    assert!(!model.is_empty());
}

#[test]
fn hyperparameter_sweep_with_metadata_leaderboard() {
    let (_acai, client) = client();
    client.upload_files(&[("/d", b"x")]).unwrap();
    client.create_file_set("in", &["/d"]).unwrap();

    for (i, epochs) in [2u32, 4, 8].iter().enumerate() {
        client
            .submit(JobRequest {
                name: format!("sweep-{i}"),
                command: format!("python train_mnist.py --epoch {epochs}"),
                input_fileset: "in".into(),
                output_fileset: format!("sweep-{i}-out"),
                resources: ResourceConfig::new(1.0, 1024),
                pool: None,
                data_commit: None,
                priority: acai::engine::Priority::Normal,
                gang: 1,
            })
            .unwrap();
    }
    client.wait_all();

    // leaderboard: best (lowest) training loss via a min query
    let best = client
        .query(ArtifactKind::Job, &[Clause::Min("training_loss".into())])
        .unwrap();
    assert_eq!(best.len(), 1);
    // more epochs => lower loss in the fallback loss model
    let doc = &best[0].1;
    assert_eq!(doc.get("arg_epoch").and_then(Json::as_f64), Some(8.0));
}

#[test]
fn profile_then_autoprovision_then_submit() {
    let (_acai, client) = client();
    client.upload_files(&[("/d", b"x")]).unwrap();
    client.create_file_set("in", &["/d"]).unwrap();

    client
        .profile("mnist", "python train_mnist.py --epoch {1,2,3}", "in")
        .unwrap();
    let decision = client
        .autoprovision("mnist", &[20.0], Objective::MinCost { max_runtime: 200.0 })
        .unwrap();
    assert!(decision.predicted_runtime <= 200.0);

    let job = client
        .submit_provisioned("mnist", &[20.0], &decision, "in", "final-model")
        .unwrap();
    client.wait_all();
    let record = client.job(job).unwrap();
    assert_eq!(record.state, JobState::Finished);
    assert_eq!(record.spec.resources.vcpus, decision.config.vcpus);
    // the measured runtime respects the constraint (noise-free platform)
    assert!(record.runtime_secs.unwrap() <= 200.0 * 1.05);
}

#[test]
fn cross_project_isolation_through_sdk() {
    let acai = Arc::new(Acai::boot_default());
    let root = acai.credentials.root_token().to_string();
    let (_p1, t1) = acai.credentials.create_project(&root, "a", "u").unwrap();
    let (_p2, t2) = acai.credentials.create_project(&root, "b", "u").unwrap();
    let c1 = Client::connect(acai.clone(), &t1).unwrap();
    let c2 = Client::connect(acai.clone(), &t2).unwrap();

    c1.upload_files(&[("/secret", b"p1-data")]).unwrap();
    c1.create_file_set("s", &["/secret"]).unwrap();
    // project b sees neither files, file sets, metadata, nor provenance
    assert!(c2.download("/secret", None).is_err());
    assert!(c2.list_file_sets().is_empty());
    assert!(c2.query(ArtifactKind::FileSet, &[]).unwrap().is_empty());
    assert!(c2.provenance_graph().0.is_empty());
    assert_eq!(c1.provenance_graph().0, vec!["s:1"]);
}

#[test]
fn tagging_and_rich_queries() {
    let (_acai, client) = client();
    client.upload_files(&[("/d", b"x")]).unwrap();
    client.create_file_set("exp", &["/d"]).unwrap();
    client.tag(
        ArtifactKind::FileSet,
        "exp:1",
        &[
            ("model".into(), Json::from("BERT")),
            ("precision".into(), Json::from(0.72)),
        ],
    );
    // the paper's flagship query: creator + model + precision range
    let hits = client
        .query(
            ArtifactKind::FileSet,
            &[
                Clause::eq("creator", "alice"),
                Clause::eq("model", "BERT"),
                Clause::gte("precision", 0.5),
            ],
        )
        .unwrap();
    assert_eq!(hits.len(), 1);
}

#[test]
fn acl_protects_files_and_filesets_across_users() {
    // §7.1.1 (future work, implemented): POSIX-style permissions
    let acai = Arc::new(Acai::boot_default());
    let root = acai.credentials.root_token().to_string();
    let (_p, alice_tok) = acai.credentials.create_project(&root, "nlp", "alice").unwrap();
    let bob_tok = acai.credentials.create_user(&alice_tok, "bob").unwrap();
    let alice = Client::connect(acai.clone(), &alice_tok).unwrap();
    let bob = Client::connect(acai.clone(), &bob_tok).unwrap();

    alice.upload_files(&[("/data/secret.bin", b"alice-only")]).unwrap();
    alice
        .protect_file("/data/secret.bin", acai::datalake::Mode::PRIVATE)
        .unwrap();
    // bob can neither read nor overwrite
    assert_eq!(bob.download("/data/secret.bin", None).unwrap_err().status(), 403);
    assert_eq!(
        bob.upload_files(&[("/data/secret.bin", b"evil")]).unwrap_err().status(),
        403
    );
    // alice still can
    assert_eq!(alice.download("/data/secret.bin", None).unwrap(), b"alice-only");

    // protected fileset: bob reads but cannot republish a new version
    alice.upload_files(&[("/data/shared.bin", b"x")]).unwrap();
    alice.create_file_set("corpus", &["/data/shared.bin"]).unwrap();
    alice
        .protect_file_set("corpus", acai::datalake::Mode::PROTECTED)
        .unwrap();
    assert_eq!(
        bob.create_file_set("corpus", &["/data/shared.bin"]).unwrap_err().status(),
        403
    );
    // unguarded resources stay project-shared (backward compatible)
    bob.upload_files(&[("/data/open.bin", b"ok")]).unwrap();
}

#[test]
fn listing_respects_acls_like_download_does() {
    // regression: list_files / list_file_sets used to skip the ACL read
    // check, letting unauthorized users enumerate paths they could not
    // download
    let acai = Arc::new(Acai::boot_default());
    let root = acai.credentials.root_token().to_string();
    let (_p, alice_tok) = acai.credentials.create_project(&root, "nlp", "alice").unwrap();
    let bob_tok = acai.credentials.create_user(&alice_tok, "bob").unwrap();
    let alice = Client::connect(acai.clone(), &alice_tok).unwrap();
    let bob = Client::connect(acai.clone(), &bob_tok).unwrap();

    alice
        .upload_files(&[("/data/secret.bin", b"x"), ("/data/open.bin", b"y")])
        .unwrap();
    alice
        .protect_file("/data/secret.bin", acai::datalake::Mode::PRIVATE)
        .unwrap();
    alice.create_file_set("hidden", &["/data/secret.bin"]).unwrap();
    alice.create_file_set("shared", &["/data/open.bin"]).unwrap();
    alice
        .protect_file_set("hidden", acai::datalake::Mode::PRIVATE)
        .unwrap();

    // bob cannot download the secret — so he must not list it either
    assert_eq!(bob.download("/data/secret.bin", None).unwrap_err().status(), 403);
    let listed: Vec<String> = bob.list_files("/").into_iter().map(|(p, _)| p).collect();
    assert_eq!(listed, vec!["/data/open.bin".to_string()]);
    let sets: Vec<String> = bob.list_file_sets().into_iter().map(|(n, _)| n).collect();
    assert_eq!(sets, vec!["shared".to_string()]);

    // the owner still sees everything
    assert_eq!(alice.list_files("/").len(), 2);
    assert_eq!(alice.list_file_sets().len(), 2);

    // the leak must also be closed on the adjacent read surfaces:
    // metadata documents and the provenance graph
    use acai::sdk::AcaiApi;
    assert_eq!(
        bob.metadata_doc(ArtifactKind::FileSet, "hidden:1").unwrap_err().status(),
        403
    );
    assert!(alice.metadata_doc(ArtifactKind::FileSet, "hidden:1").is_ok());
    let (bob_nodes, _) = bob.provenance().unwrap();
    assert!(bob_nodes.contains(&"shared:1".to_string()), "{bob_nodes:?}");
    assert!(!bob_nodes.contains(&"hidden:1".to_string()), "{bob_nodes:?}");
    assert_eq!(bob.trace("hidden", 1, acai::api::dto::TraceDir::Backward).unwrap_err().status(), 403);
    let hits = bob.metadata_query(ArtifactKind::FileSet, &[]).unwrap();
    assert!(hits.iter().all(|(id, _)| !id.starts_with("hidden:")), "{hits:?}");
}

#[test]
fn pipeline_chains_stages_and_cache_serves_repeat_inputs() {
    // §7.2 pipelines + §7.1.2 inter-job cache, through the public API
    use acai::engine::pipeline::{Pipeline, Stage};
    let (acai, client) = client();
    client.upload_files(&[("/raw.bin", b"raw-data")]).unwrap();
    client.create_file_set("raw", &["/raw.bin"]).unwrap();

    let pipeline = Pipeline {
        name: "flow".into(),
        input_fileset: "raw".into(),
        stages: vec![
            Stage {
                name: "feat".into(),
                command: "python train_mnist.py --epoch 1".into(),
                output_fileset: "features".into(),
                resources: ResourceConfig::new(1.0, 1024),
                pool: None,
                data_commit: None,
            },
            Stage {
                name: "train".into(),
                command: "python train_mnist.py --epoch 2".into(),
                output_fileset: "model".into(),
                resources: ResourceConfig::new(1.0, 1024),
                pool: None,
                data_commit: None,
            },
        ],
    };
    let run = pipeline
        .run(&acai.engine, client.identity().project, client.identity().user)
        .unwrap();
    assert_eq!(run.final_output.0, "model");

    // run five more jobs against the SAME input fileset version: the
    // cache serves them without touching the object store again
    let (h0, _m0, _) = acai.datalake.cache.stats();
    for i in 0..5 {
        client
            .submit(JobRequest {
                name: format!("re-{i}"),
                command: "python train_mnist.py --epoch 1".into(),
                input_fileset: "raw:1".into(),
                output_fileset: format!("re-{i}-out"),
                resources: ResourceConfig::new(0.5, 512),
                pool: None,
                data_commit: None,
                priority: acai::engine::Priority::Normal,
                gang: 1,
            })
            .unwrap();
    }
    client.wait_all();
    let (h1, _m1, bytes) = acai.datalake.cache.stats();
    assert!(h1 - h0 >= 5, "cache hits {h0} -> {h1}");
    assert!(bytes > 0);
}

#[test]
fn gc_reclaims_unpinned_versions_via_public_surface() {
    // §7.1.3 data cleaning through the data-lake facade
    use acai::datalake::gc::GarbageCollector;
    let (acai, client) = client();
    for content in [&b"v1"[..], b"v2", b"v3"] {
        client.upload_files(&[("/d.bin", content)]).unwrap();
    }
    client.create_file_set("pin", &["/d.bin#2"]).unwrap();
    let gc = GarbageCollector::new(&acai.datalake);
    let reclaimed = gc.sweep(client.identity().project).unwrap();
    assert_eq!(reclaimed.reclaimable_bytes, 4); // v1 + v3
    assert!(client.download("/d.bin", Some(2)).is_ok());
    assert!(client.download("/d.bin", Some(1)).is_err());
}
