//! Failure injection: dropped uploads, failing containers, stragglers,
//! and crash recovery of the session journal — the paths §4.4.3 and
//! §4.2.2 exist for.

use acai::cluster::ResourceConfig;
use acai::datalake::SessionState;
use acai::engine::{JobSpec, JobState};
use acai::ids::{ProjectId, UserId};
use acai::kvstore::KvStore;
use acai::{Acai, PlatformConfig};

const P: ProjectId = ProjectId(1);
const U: UserId = UserId(1);

fn seed(acai: &Acai) {
    acai.datalake.storage.upload(P, &[("/d", b"x")]).unwrap();
    acai.datalake.filesets.create(P, "in", &["/d"], "u").unwrap();
}

fn job(i: usize) -> JobSpec {
    JobSpec {
        project: P,
        user: U,
        name: format!("j{i}"),
        command: "python train_mnist.py --epoch 2".into(),
        input_fileset: "in".into(),
        output_fileset: format!("o{i}"),
        resources: ResourceConfig::new(1.0, 1024),
    }
}

#[test]
fn container_failures_mark_jobs_failed_and_free_quota() {
    let mut config = PlatformConfig::default();
    config.cluster.failure_rate = 0.5;
    config.cluster.seed = 7;
    config.quota_k = 2;
    let acai = Acai::boot(config).unwrap();
    seed(&acai);
    let ids: Vec<_> = (0..12).map(|i| acai.engine.submit(job(i)).unwrap()).collect();
    acai.engine.run_until_idle();
    let mut finished = 0;
    let mut failed = 0;
    for id in ids {
        match acai.engine.registry.get(id).unwrap().state {
            JobState::Finished => finished += 1,
            JobState::Failed => failed += 1,
            s => panic!("job stuck in {s:?}"),
        }
    }
    assert!(finished > 0 && failed > 0, "finished={finished} failed={failed}");
    // all resources freed
    assert_eq!(acai.cluster.utilization().0, 0);
    // failed jobs are still billed for their runtime (the paper bills
    // resource-time, not success)
}

#[test]
fn failed_jobs_produce_no_output_fileset_or_provenance() {
    let mut config = PlatformConfig::default();
    config.cluster.failure_rate = 1.0;
    let acai = Acai::boot(config).unwrap();
    seed(&acai);
    let id = acai.engine.submit(job(0)).unwrap();
    acai.engine.run_until_idle();
    assert_eq!(acai.engine.registry.get(id).unwrap().state, JobState::Failed);
    assert!(acai.datalake.filesets.latest_version(P, "o0").is_none());
    assert!(acai.datalake.provenance.forward(P, "in", 1).is_empty());
    // error is recorded in the logs
    let logs = acai.engine.logs.get(id);
    assert!(logs.iter().any(|l| l.contains("failed")), "{logs:?}");
}

#[test]
fn stragglers_dont_block_the_profile_barrier() {
    let mut config = PlatformConfig::default();
    config.cluster.straggler_rate = 0.04; // ~1 straggler in 27 trials
    config.cluster.straggler_factor = 50.0;
    config.cluster.seed = 3;
    let acai = Acai::boot(config).unwrap();
    seed(&acai);
    let t0 = acai.clock.now();
    acai.profiler
        .profile("t", "python train_mnist.py --epoch {1,2,3}", P, U, "in")
        .unwrap();
    let fitted = acai.profiler.by_name("t").unwrap();
    // the barrier waited for >= 95% (26 of 27), not for the straggler
    assert!(fitted.trials.len() >= 26, "{}", fitted.trials.len());
    // the fit is still usable
    assert!((fitted.theta[3] - 1.0).abs() < 0.25, "{:?}", fitted.theta);
    let elapsed = acai.clock.now() - t0;
    assert!(elapsed > 0.0);
}

#[test]
fn upload_failure_then_retry_preserves_version_density() {
    let acai = Acai::boot_default();
    let storage = &acai.datalake.storage;
    storage.upload(P, &[("/f", b"v1")]).unwrap();

    // simulate a flaky network: 3 failed upload attempts
    for _ in 0..3 {
        let objects = acai_objects(&acai);
        objects.inject_put_failures(1);
        let (id, grants) = storage.start_session(P, &["/f"]).unwrap();
        assert!(objects.put_presigned(&grants[0].1.token, b"x".to_vec()).is_err());
        storage.abort_session(id).unwrap();
    }
    let v = storage.upload(P, &[("/f", b"v2")]).unwrap();
    assert_eq!(v[0].1, 2, "failed attempts must not burn versions");
}

/// Reach the object store through the session-granting path.
fn acai_objects(acai: &Acai) -> acai::objectstore::ObjectStore {
    // The platform shares one object store; grab it via a presign round
    // trip (the storage server is the only holder). For tests we rebuild
    // access by uploading through storage, so here we just expose the
    // store the platform was built with.
    acai.object_store()
}

#[test]
fn session_journal_survives_crash_and_can_be_continued() {
    let dir = std::env::temp_dir().join(format!("acai-crash-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let journal = dir.join("crash.log");
    let _ = std::fs::remove_file(&journal);

    let kv = KvStore::open(&journal).unwrap();
    kv.put(
        "sessions",
        "sess-1",
        acai::json::parse(
            r#"{"project":1,"state":"pending","created":0,
                "files":[{"path":"/a","key":"obj-9","uploaded":true},
                          {"path":"/b","key":"obj-10","uploaded":false}]}"#,
        )
        .unwrap(),
    )
    .unwrap();
    // crash + restart
    let kv2 = kv.reopen().unwrap();
    let row = kv2.get("sessions", "sess-1").unwrap();
    let session =
        acai::datalake::UploadSession::from_json(acai::ids::SessionId(1), &row).unwrap();
    assert!(matches!(
        session.state,
        SessionState::Pending { uploaded: 1, total: 2 }
    ));
    assert!(!session.complete());
    let _ = std::fs::remove_file(&journal);
}

#[test]
fn presigned_token_abuse_is_rejected() {
    let acai = Acai::boot_default();
    let objects = acai.object_store();
    let storage = &acai.datalake.storage;
    let (_id, grants) = storage.start_session(P, &["/f"]).unwrap();
    let token = &grants[0].1.token;
    objects.put_presigned(token, b"ok".to_vec()).unwrap();
    // replay: rejected
    assert_eq!(
        objects.put_presigned(token, b"evil".to_vec()).unwrap_err().status(),
        401
    );
    // forged token: rejected
    assert_eq!(
        objects.put_presigned("ps-put-ffff", b"evil".to_vec()).unwrap_err().status(),
        401
    );
}

#[test]
fn mixed_failures_and_stragglers_under_load() {
    let mut config = PlatformConfig::default();
    config.cluster.failure_rate = 0.15;
    config.cluster.straggler_rate = 0.1;
    config.cluster.straggler_factor = 5.0;
    config.noise = 0.05;
    config.quota_k = 4;
    config.cluster.seed = 99;
    let acai = Acai::boot(config).unwrap();
    seed(&acai);
    let ids: Vec<_> = (0..40).map(|i| acai.engine.submit(job(i)).unwrap()).collect();
    acai.engine.run_until_idle();
    for id in ids {
        let state = acai.engine.registry.get(id).unwrap().state;
        assert!(state.is_terminal(), "{id} stuck in {state:?}");
    }
    assert_eq!(acai.cluster.running_count(), 0);
    assert_eq!(acai.cluster.utilization().0, 0);
}
