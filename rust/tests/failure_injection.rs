//! Failure injection: dropped uploads, failing containers, stragglers,
//! and crash recovery of the session journal — the paths §4.4.3 and
//! §4.2.2 exist for.

use acai::cluster::{ClusterConfig, NodeSpec, PoolConfig, ResourceConfig};
use acai::datalake::SessionState;
use acai::engine::{JobSpec, JobState};
use acai::ids::{ProjectId, UserId};
use acai::kvstore::KvStore;
use acai::{Acai, PlatformConfig};

const P: ProjectId = ProjectId(1);
const U: UserId = UserId(1);

fn seed(acai: &Acai) {
    acai.datalake.storage.upload(P, &[("/d", b"x")]).unwrap();
    acai.datalake.filesets.create(P, "in", &["/d"], "u").unwrap();
}

fn job(i: usize) -> JobSpec {
    JobSpec {
        project: P,
        user: U,
        name: format!("j{i}"),
        command: "python train_mnist.py --epoch 2".into(),
        input_fileset: "in".into(),
        output_fileset: format!("o{i}"),
        resources: ResourceConfig::new(1.0, 1024),
        pool: None,
        data_commit: None,
        priority: acai::engine::Priority::Normal,
        gang: 1,
    }
}

#[test]
fn container_failures_mark_jobs_failed_and_free_quota() {
    let mut config = PlatformConfig::default();
    config.cluster.failure_rate = 0.5;
    config.cluster.seed = 7;
    config.quota_k = 2;
    let acai = Acai::boot(config).unwrap();
    seed(&acai);
    let ids: Vec<_> = (0..12).map(|i| acai.engine.submit(job(i)).unwrap()).collect();
    acai.engine.run_until_idle();
    let mut finished = 0;
    let mut failed = 0;
    for id in ids {
        match acai.engine.registry.get(id).unwrap().state {
            JobState::Finished => finished += 1,
            JobState::Failed => failed += 1,
            s => panic!("job stuck in {s:?}"),
        }
    }
    assert!(finished > 0 && failed > 0, "finished={finished} failed={failed}");
    // all resources freed
    assert_eq!(acai.cluster.utilization().0, 0);
    // failed jobs are still billed for their runtime (the paper bills
    // resource-time, not success)
}

#[test]
fn failed_jobs_produce_no_output_fileset_or_provenance() {
    let mut config = PlatformConfig::default();
    config.cluster.failure_rate = 1.0;
    let acai = Acai::boot(config).unwrap();
    seed(&acai);
    let id = acai.engine.submit(job(0)).unwrap();
    acai.engine.run_until_idle();
    assert_eq!(acai.engine.registry.get(id).unwrap().state, JobState::Failed);
    assert!(acai.datalake.filesets.latest_version(P, "o0").is_none());
    assert!(acai.datalake.provenance.forward(P, "in", 1).is_empty());
    // error is recorded in the logs
    let logs = acai.engine.logs.get(id);
    assert!(logs.iter().any(|l| l.contains("failed")), "{logs:?}");
}

#[test]
fn stragglers_dont_block_the_profile_barrier() {
    let mut config = PlatformConfig::default();
    config.cluster.straggler_rate = 0.04; // ~1 straggler in 27 trials
    config.cluster.straggler_factor = 50.0;
    config.cluster.seed = 3;
    let acai = Acai::boot(config).unwrap();
    seed(&acai);
    let t0 = acai.clock.now();
    acai.profiler
        .profile("t", "python train_mnist.py --epoch {1,2,3}", P, U, "in")
        .unwrap();
    let fitted = acai.profiler.by_name("t").unwrap();
    // the barrier waited for >= 95% (26 of 27), not for the straggler
    assert!(fitted.trials.len() >= 26, "{}", fitted.trials.len());
    // the fit is still usable
    assert!((fitted.theta[3] - 1.0).abs() < 0.25, "{:?}", fitted.theta);
    let elapsed = acai.clock.now() - t0;
    assert!(elapsed > 0.0);
}

#[test]
fn upload_failure_then_retry_preserves_version_density() {
    let acai = Acai::boot_default();
    let storage = &acai.datalake.storage;
    storage.upload(P, &[("/f", b"v1")]).unwrap();

    // simulate a flaky network: 3 failed upload attempts
    for _ in 0..3 {
        let objects = acai_objects(&acai);
        objects.inject_put_failures(1);
        let (id, grants) = storage.start_session(P, &["/f"]).unwrap();
        assert!(objects.put_presigned(&grants[0].1.token, b"x".to_vec()).is_err());
        storage.abort_session(id).unwrap();
    }
    let v = storage.upload(P, &[("/f", b"v2")]).unwrap();
    assert_eq!(v[0].1, 2, "failed attempts must not burn versions");
}

/// Reach the object store through the session-granting path.
fn acai_objects(acai: &Acai) -> acai::objectstore::ObjectStore {
    // The platform shares one object store; grab it via a presign round
    // trip (the storage server is the only holder). For tests we rebuild
    // access by uploading through storage, so here we just expose the
    // store the platform was built with.
    acai.object_store()
}

#[test]
fn session_journal_survives_crash_and_can_be_continued() {
    let dir = std::env::temp_dir().join(format!("acai-crash-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let journal = dir.join("crash.log");
    let _ = std::fs::remove_file(&journal);

    let kv = KvStore::open(&journal).unwrap();
    kv.put(
        "sessions",
        "sess-1",
        acai::json::parse(
            r#"{"project":1,"state":"pending","created":0,
                "files":[{"path":"/a","key":"obj-9","uploaded":true},
                          {"path":"/b","key":"obj-10","uploaded":false}]}"#,
        )
        .unwrap(),
    )
    .unwrap();
    // crash + restart
    let kv2 = kv.reopen().unwrap();
    let row = kv2.get("sessions", "sess-1").unwrap();
    let session =
        acai::datalake::UploadSession::from_json(acai::ids::SessionId(1), &row).unwrap();
    assert!(matches!(
        session.state,
        SessionState::Pending { uploaded: 1, total: 2 }
    ));
    assert!(!session.complete());
    let _ = std::fs::remove_file(&journal);
}

#[test]
fn presigned_token_abuse_is_rejected() {
    let acai = Acai::boot_default();
    let objects = acai.object_store();
    let storage = &acai.datalake.storage;
    let (_id, grants) = storage.start_session(P, &["/f"]).unwrap();
    let token = &grants[0].1.token;
    objects.put_presigned(token, b"ok".to_vec()).unwrap();
    // replay: rejected
    assert_eq!(
        objects.put_presigned(token, b"evil".to_vec()).unwrap_err().status(),
        401
    );
    // forged token: rejected
    assert_eq!(
        objects.put_presigned("ps-put-ffff", b"evil".to_vec()).unwrap_err().status(),
        401
    );
}

/// Platform with a small fixed on-demand pool plus a cheap, revocable
/// spot pool (the ISSUE-4 elastic substrate under storm conditions).
fn spot_platform(seed: u64, preemption_mean: f64, checkpoint_secs: f64) -> Acai {
    let node = NodeSpec::new(4.0, 8192);
    let mut config = PlatformConfig::default();
    config.checkpoint_secs = checkpoint_secs;
    config.cluster = ClusterConfig {
        pools: vec![
            PoolConfig::on_demand("ondemand", node, 2),
            PoolConfig::spot("spot", node, 6, 0.3, preemption_mean),
        ],
        seed,
        ..Default::default()
    };
    let acai = Acai::boot(config).unwrap();
    seed_data(&acai);
    acai
}

fn seed_data(acai: &Acai) {
    seed(acai);
}

#[test]
fn spot_storm_same_seed_identical_placement_preemptions_and_cost() {
    // a seeded storm: every job pinned to the revocable pool; the run
    // must complete despite the revocations, and two runs with the same
    // seed must agree bit-for-bit on cost and event counts
    let run = |seed: u64| {
        let acai = spot_platform(seed, 8.0, 2.0);
        let mut ids = Vec::new();
        for i in 0..16 {
            let mut spec = job(i);
            spec.command = "python train_mnist.py --epoch 6".into();
            spec.pool = Some("spot".into());
            ids.push(acai.engine.submit(spec).unwrap());
        }
        acai.engine.run_until_idle();
        let mut total_cost = 0.0f64;
        let mut job_preemptions = 0u64;
        let mut runtimes = Vec::new();
        for id in &ids {
            let r = acai.engine.registry.get(*id).unwrap();
            assert_eq!(r.state, JobState::Finished, "{id} stuck as {:?}", r.state);
            total_cost += r.cost.unwrap();
            job_preemptions += r.preemptions;
            runtimes.push(r.runtime_secs.unwrap().to_bits());
        }
        // everything returned: no leaked capacity on revoked nodes
        assert_eq!(acai.cluster.utilization().0, 0);
        (total_cost, job_preemptions, runtimes, acai.cluster.counters())
    };
    let (cost_a, pre_a, runtimes_a, counters_a) = run(0xBEEF);
    let (cost_b, pre_b, runtimes_b, counters_b) = run(0xBEEF);
    assert_eq!(cost_a.to_bits(), cost_b.to_bits(), "{cost_a} vs {cost_b}");
    assert_eq!(pre_a, pre_b);
    assert_eq!(runtimes_a, runtimes_b, "per-job timelines must replay exactly");
    assert_eq!(counters_a, counters_b);
    // it was a storm, and the platform rode it out
    assert!(
        counters_a.preempted_containers >= 5,
        "want a real storm, got {counters_a:?}"
    );
    assert!(counters_a.preempted_nodes >= 2, "{counters_a:?}");
    // a different seed produces a different storm
    let (cost_c, _, _, counters_c) = run(0xD00D);
    assert!(
        cost_a.to_bits() != cost_c.to_bits()
            || counters_a.preempted_containers != counters_c.preempted_containers,
        "different seeds should not replay the same storm"
    );
}

#[test]
fn checkpointed_resume_reworks_less_than_a_full_rerun() {
    let long_job = || {
        let mut spec = job(0);
        spec.command = "python train_mnist.py --epoch 20".into();
        spec
    };
    // baseline: the same job on preemption-free capacity
    let baseline = {
        let acai = Acai::boot_default();
        seed_data(&acai);
        let id = acai.engine.submit(long_job()).unwrap();
        acai.engine.run_until_idle();
        acai.engine.registry.get(id).unwrap().runtime_secs.unwrap()
    };

    // spot-only platform with aggressive revocation: the ~133 s job is
    // interrupted many times (mean 15 s between revocations) but
    // checkpoints every 5 s of progress
    let node = NodeSpec::new(4.0, 8192);
    let mut config = PlatformConfig::default();
    config.checkpoint_secs = 5.0;
    config.cluster = ClusterConfig {
        pools: vec![PoolConfig {
            name: "spot".into(),
            spec: node,
            price_multiplier: 0.3,
            min_nodes: 1,
            max_nodes: 1,
            preemption_mean_secs: 15.0,
        }],
        seed: 0xACA1,
        ..Default::default()
    };
    let acai = Acai::boot(config).unwrap();
    seed_data(&acai);
    let mut spec = long_job();
    spec.pool = Some("spot".into());
    let id = acai.engine.submit(spec).unwrap();
    acai.engine.run_until_idle();

    let r = acai.engine.registry.get(id).unwrap();
    assert_eq!(r.state, JobState::Finished);
    assert!(r.preemptions >= 1, "expected at least one revocation: {r:?}");
    let runtime = r.runtime_secs.unwrap();
    // resumed from checkpoints: total billed time is the planned run
    // plus strictly less than one checkpoint interval of rework per
    // preemption — never a full re-run per revocation
    assert!(runtime >= baseline - 1e-6, "{runtime} < baseline {baseline}");
    assert!(
        runtime < baseline + r.preemptions as f64 * 5.0 + 1e-6,
        "rework exceeded the checkpoint bound: runtime {runtime}, baseline {baseline}, \
         preemptions {}",
        r.preemptions
    );
    assert!(
        runtime < 2.0 * baseline,
        "rework time must stay below a full re-run: {runtime} vs {baseline}"
    );
    // the monitor folded the agent's checkpoint tags into a resume point
    assert_eq!(acai.engine.monitor.resume_point(id), r.checkpoint);
    assert!(acai
        .engine
        .logs
        .get(id)
        .iter()
        .any(|l| l.contains("[[acai]] checkpoint=")));
    // spot pricing: the interrupted run still billed at the pool's
    // multiplier — cheaper than the on-demand baseline despite rework
    let od_cost = acai.pricing.cost(r.spec.resources, baseline);
    assert!(
        r.cost.unwrap() < od_cost,
        "spot run should be cheaper: {} vs on-demand {}",
        r.cost.unwrap(),
        od_cost
    );
}

#[test]
fn mixed_failures_and_stragglers_under_load() {
    let mut config = PlatformConfig::default();
    config.cluster.failure_rate = 0.15;
    config.cluster.straggler_rate = 0.1;
    config.cluster.straggler_factor = 5.0;
    config.noise = 0.05;
    config.quota_k = 4;
    config.cluster.seed = 99;
    let acai = Acai::boot(config).unwrap();
    seed(&acai);
    let ids: Vec<_> = (0..40).map(|i| acai.engine.submit(job(i)).unwrap()).collect();
    acai.engine.run_until_idle();
    for id in ids {
        let state = acai.engine.registry.get(id).unwrap().state;
        assert!(state.is_terminal(), "{id} stuck in {state:?}");
    }
    assert_eq!(acai.cluster.running_count(), 0);
    assert_eq!(acai.cluster.utilization().0, 0);
}

#[test]
fn spot_revocation_mid_gang_rolls_back_the_whole_reservation() {
    // an 8-replica gang spans both spot nodes (4 slots each); revoking
    // either node preempts the gang, and the teardown must release EVERY
    // sibling slot — a preempted gang never camps on partial capacity
    let node = NodeSpec::new(4.0, 8192);
    let mut config = PlatformConfig::default();
    config.checkpoint_secs = 2.0;
    config.cluster = ClusterConfig {
        pools: vec![PoolConfig {
            name: "spot".into(),
            spec: node,
            price_multiplier: 0.3,
            min_nodes: 2,
            max_nodes: 2,
            preemption_mean_secs: 10.0,
        }],
        seed: 0xACA1,
        ..Default::default()
    };
    let acai = Acai::boot(config).unwrap();
    seed(&acai);
    let mut spec = job(0);
    spec.command = "python train_mnist.py --epoch 8".into();
    spec.resources = ResourceConfig::new(1.0, 1024);
    spec.pool = Some("spot".into());
    spec.gang = 8; // needs the whole pool
    let id = acai.engine.submit(spec).unwrap();
    acai.engine.pump();
    let mut steps = 0;
    loop {
        let r = acai.engine.registry.get(id).unwrap();
        match r.state {
            JobState::Running => assert_eq!(
                r.containers.len(),
                8,
                "running gang must hold all its slots"
            ),
            JobState::Queued => assert_eq!(
                acai.cluster.utilization().0,
                0,
                "a preempted gang must not hold partial capacity"
            ),
            _ => {}
        }
        if !acai.engine.step() {
            break;
        }
        steps += 1;
        assert!(steps < 100_000, "engine livelock");
    }
    let r = acai.engine.registry.get(id).unwrap();
    assert_eq!(r.state, JobState::Finished, "gang stuck as {:?}", r.state);
    assert!(r.preemptions >= 1, "want at least one revocation: {r:?}");
    // one revocation event per preemption, not one per dying replica
    assert!(
        r.preemptions <= acai.cluster.counters().preempted_nodes,
        "replica events double-counted: {} preemptions, {} revoked nodes",
        r.preemptions,
        acai.cluster.counters().preempted_nodes
    );
    assert_eq!(acai.cluster.utilization().0, 0);
    assert_eq!(acai.cluster.running_count(), 0);
}

#[test]
fn evicted_low_priority_job_resumes_within_the_checkpoint_bound() {
    use acai::engine::Priority;
    let one_node = |checkpoint: f64| {
        let mut config = PlatformConfig::default();
        config.checkpoint_secs = checkpoint;
        config.cluster = ClusterConfig::fixed(NodeSpec::new(4.0, 8192), 1);
        let acai = Acai::boot(config).unwrap();
        seed(&acai);
        acai
    };
    let low_spec = || {
        let mut spec = job(0);
        spec.command = "python train_mnist.py --epoch 20".into();
        spec.resources = ResourceConfig::new(4.0, 4096);
        spec.priority = Priority::Low;
        spec
    };
    // baseline: the same job alone on the same one-node cluster
    let baseline = {
        let acai = one_node(5.0);
        let id = acai.engine.submit(low_spec()).unwrap();
        acai.engine.run_until_idle();
        acai.engine.registry.get(id).unwrap().runtime_secs.unwrap()
    };

    // now the job is repeatedly evicted by whole-node high-priority work
    let acai = one_node(5.0);
    let low = acai.engine.submit(low_spec()).unwrap();
    acai.engine.pump();
    assert_eq!(acai.engine.registry.get(low).unwrap().state, JobState::Running);
    let mut highs = Vec::new();
    for k in 0..3 {
        // let the low job make real progress before the eviction, so the
        // checkpoint credit (floor to 5 s) is actually exercised
        acai.clock.advance(7.0);
        let mut spec = job(k + 1);
        spec.command = "python train_mnist.py --epoch 2".into();
        spec.resources = ResourceConfig::new(4.0, 4096);
        spec.priority = Priority::High;
        let high = acai.engine.submit(spec).unwrap();
        highs.push(high);
        acai.engine.pump(); // full node: must evict the low job
        assert_eq!(
            acai.engine.registry.get(high).unwrap().state,
            JobState::Running,
            "high-priority job {k} did not displace the low job"
        );
        // drive until the high job finishes (its completion re-pumps and
        // resumes the low job from its checkpoint)
        while !acai.engine.registry.get(high).unwrap().state.is_terminal() {
            assert!(acai.engine.step(), "engine stalled with a running high job");
        }
    }
    acai.engine.run_until_idle();

    let r = acai.engine.registry.get(low).unwrap();
    assert_eq!(r.state, JobState::Finished);
    assert_eq!(r.preemptions, 3, "one eviction per high-priority arrival");
    for high in highs {
        let h = acai.engine.registry.get(high).unwrap();
        assert_eq!(h.state, JobState::Finished);
        assert_eq!(h.preemptions, 0, "high-priority work must never be evicted");
    }
    assert_eq!(acai.engine.scheduler.counters().evictions, 3);
    let runtime = r.runtime_secs.unwrap();
    assert!(runtime >= baseline - 1e-6, "{runtime} < baseline {baseline}");
    assert!(
        runtime < baseline + r.preemptions as f64 * 5.0 + 1e-6,
        "rework exceeded the checkpoint bound: runtime {runtime}, baseline {baseline}, \
         preemptions {}",
        r.preemptions
    );
    // the eviction rode the ordinary preemption path: checkpoint logged,
    // resume point folded into the monitor
    assert_eq!(acai.engine.monitor.resume_point(low), r.checkpoint);
    assert!(acai
        .engine
        .logs
        .get(low)
        .iter()
        .any(|l| l.contains("evicted by high-priority job")));
}
