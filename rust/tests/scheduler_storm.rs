//! Fleet-scale scheduling storm: 16 tenants, 10k jobs, a 1000-node
//! cluster.  Proves the weighted-DRF scheduler (a) starves nobody,
//! (b) converges allocations to the configured weight ratios within
//! 10%, (c) is bit-identical across same-seed reruns, and (d) spends
//! a bounded number of heap decisions per pump (the de-O(n²) claim:
//! work per pump tracks launches + retirals, never total backlog).

use std::sync::Arc;

use acai::api::make_handler;
use acai::cluster::{ClusterConfig, NodeSpec, ResourceConfig};
use acai::engine::{Demand, JobSpec, JobState, Priority, QueueKey, Scheduler, SchedulerCounters};
use acai::httpd::Server;
use acai::ids::{JobId, ProjectId, UserId};
use acai::json::Json;
use acai::prng::Rng;
use acai::sdk::{AcaiApi, Client, JobRequest, RemoteClient};
use acai::{Acai, PlatformConfig};

const TENANTS: u64 = 16;
const JOBS: u64 = 10_000;
/// 1000 nodes × 4 one-vCPU slots.
const SLOTS: u64 = 4_000;

/// Weights cycle 4:2:1:1 over the 16 tenants (Σ = 32).
fn weight_of(project: u64) -> f64 {
    [4.0, 2.0, 1.0, 1.0][((project - 1) % 4) as usize]
}

/// What one scheduler-level storm run observed, bit-exactly.
struct StormTrace {
    /// `(project, job)` in global launch order.
    sequence: Vec<(u64, u64)>,
    /// Jobs each project launched in the very first pump (the cluster
    /// fills from empty, so these counts ARE the converged shares).
    first_batch: Vec<u64>,
    /// Weighted dominant share per project right after the first pump.
    share_bits: Vec<u64>,
    counters: SchedulerCounters,
}

/// Drive a bare [`Scheduler`] through the full storm: seed 10k jobs
/// across 16 weighted tenants, pump against a modeled 4000-slot
/// cluster, retire seeded slices of running work between pumps.
fn run_storm(seed: u64) -> StormTrace {
    let scheduler = Scheduler::new(100_000); // quota never binds here
    scheduler.set_capacity(SLOTS * 1000, SLOTS * 1024);
    for p in 1..=TENANTS {
        scheduler.set_weight(ProjectId(p), weight_of(p)).unwrap();
    }

    let mut rng = Rng::new(seed);
    let demand = Demand { milli_vcpus: 1000, mem_mb: 1024 };
    for j in 1..=JOBS {
        let key = (ProjectId(1 + rng.below(TENANTS)), UserId(1 + rng.below(4)));
        scheduler.enqueue_job(key, JobId(j), demand, Priority::Normal);
    }

    let mut free = SLOTS;
    let mut running: Vec<(QueueKey, JobId)> = Vec::new();
    let mut sequence: Vec<(u64, u64)> = Vec::new();
    let mut first_batch = vec![0u64; TENANTS as usize + 1];
    let mut share_bits = Vec::new();
    let mut pumps = 0u64;
    // Heap entries pending at the next pump: the 10k enqueue touches
    // before the first, then whatever the between-pump retirals push.
    let mut touched_since_last = JOBS;

    while scheduler.any_queued() || !running.is_empty() {
        let before = scheduler.counters().decisions;
        let batch = scheduler.launchable_within(free * 1000, free * 1024);
        let spent = scheduler.counters().decisions - before;
        // (d) decision bound: stale entries from the touches since the
        // last pump, one pop per launch (each launch re-touches), one
        // blocked re-entry per tenant — never the whole backlog.
        assert!(
            spent <= touched_since_last + batch.len() as u64 + 2 * TENANTS + 8,
            "pump {pumps}: {spent} decisions for {} launches ({touched_since_last} touched)",
            batch.len(),
        );
        assert!(batch.len() as u64 <= free, "pump overfilled the cluster");
        if pumps == 0 {
            for ((project, _), _) in &batch {
                first_batch[project.raw() as usize] += 1;
            }
            let mut shares = scheduler.project_shares();
            shares.sort_by_key(|s| s.project.raw());
            share_bits = shares.iter().map(|s| s.share.to_bits()).collect();
        }
        free -= batch.len() as u64;
        for (key, job) in batch {
            sequence.push((key.0.raw(), job.raw()));
            running.push((key, job));
        }

        // retire a seeded slice of the running set
        let retire = if running.is_empty() {
            0
        } else {
            1 + rng.below((running.len() as u64).min(257))
        };
        for _ in 0..retire {
            let i = rng.below(running.len() as u64) as usize;
            let (key, job) = running.swap_remove(i);
            scheduler.on_terminal(key, job);
            free += 1;
        }
        touched_since_last = retire;
        pumps += 1;
    }

    assert_eq!(sequence.len() as u64, JOBS, "every job must launch exactly once");
    StormTrace {
        sequence,
        first_batch,
        share_bits,
        counters: scheduler.counters(),
    }
}

/// (a) + (b): nobody starves, and the first full pump splits the
/// cluster within 10% of the 4:2:1:1 weight ratios.
#[test]
fn storm_starves_no_tenant_and_converges_to_weight_ratios() {
    let trace = run_storm(0xACA1_5708);

    // (a) starvation-freedom by launch position: every tenant's FIRST
    // job launches before ANY tenant's 100th.
    let mut first = vec![u64::MAX; TENANTS as usize + 1];
    let mut count = vec![0u64; TENANTS as usize + 1];
    let mut hundredth = vec![u64::MAX; TENANTS as usize + 1];
    for (i, (project, _)) in trace.sequence.iter().enumerate() {
        let p = *project as usize;
        if count[p] == 0 {
            first[p] = i as u64;
        }
        count[p] += 1;
        if count[p] == 100 {
            hundredth[p] = i as u64;
        }
    }
    let last_first = (1..=TENANTS as usize).map(|p| first[p]).max().unwrap();
    let first_hundredth = (1..=TENANTS as usize).map(|p| hundredth[p]).min().unwrap();
    assert!(first_hundredth != u64::MAX, "some tenant never reached 100 launches");
    assert!(
        last_first < first_hundredth,
        "a tenant starved: latest first launch at {last_first}, \
         earliest 100th at {first_hundredth}"
    );

    // (b) the first pump fills an empty cluster, so per-tenant counts
    // are the converged weighted allocation: SLOTS * w / Σw ± 10%.
    let total_weight: f64 = (1..=TENANTS).map(weight_of).sum();
    for p in 1..=TENANTS {
        let expect = SLOTS as f64 * weight_of(p) / total_weight;
        let got = trace.first_batch[p as usize] as f64;
        assert!(
            (got - expect).abs() <= 0.1 * expect,
            "tenant {p} (weight {}): {got} first-pump launches, expected {expect:.1} ±10%",
            weight_of(p),
        );
    }

    // after the first pump every tenant still has a backlog, so the
    // weighted dominant shares must be level (water-filling).
    let shares: Vec<f64> = trace.share_bits.iter().map(|b| f64::from_bits(*b)).collect();
    let mean = shares.iter().sum::<f64>() / shares.len() as f64;
    assert!(mean > 0.0);
    for (i, s) in shares.iter().enumerate() {
        assert!(
            (s - mean).abs() <= 0.1 * mean,
            "tenant {}: weighted share {s} strays >10% from level {mean}",
            i + 1,
        );
    }
}

/// (c) same seed ⇒ the same storm, bit for bit: launch order, first
/// pump split, post-pump shares, and every monotonic counter.
#[test]
fn storm_is_bit_identical_across_same_seed_reruns() {
    let a = run_storm(0xACA1_BEEF);
    let b = run_storm(0xACA1_BEEF);
    assert_eq!(a.sequence, b.sequence, "launch order diverged");
    assert_eq!(a.first_batch, b.first_batch);
    assert_eq!(a.share_bits, b.share_bits, "shares diverged bit-wise");
    assert_eq!(a.counters, b.counters, "decision counters diverged");

    // different seed ⇒ a different storm (the suite is not vacuous)
    let c = run_storm(0xACA1_F00D);
    assert_ne!(a.sequence, c.sequence);
}

/// One full-engine storm run: 1000 nodes, 10k mixed-priority jobs
/// (some gangs), weighted 4:2:1:1 over 16 tenants.  Returns the
/// bit-exact per-job outcome in submission order plus the counters.
fn engine_storm(seed: u64) -> (Vec<(u64, u64, u64, u64)>, SchedulerCounters) {
    let acai = Acai::boot(PlatformConfig {
        cluster: ClusterConfig::fixed(NodeSpec::new(4.0, 16384), 1000),
        quota_k: 10_000, // weights, not the per-user quota, drive the split
        ..Default::default()
    })
    .unwrap();
    for p in 1..=TENANTS {
        acai.engine
            .scheduler
            .set_weight(ProjectId(p), weight_of(p))
            .unwrap();
    }

    let mut rng = Rng::new(seed);
    let mut ids = Vec::with_capacity(JOBS as usize);
    for i in 0..JOBS {
        let project = 1 + rng.below(TENANTS);
        let priority = match rng.below(100) {
            0..=9 => Priority::Low,
            10..=14 => Priority::High,
            _ => Priority::Normal,
        };
        let gang = if rng.below(100) < 3 { 2 + rng.below(3) as u32 } else { 1 };
        let epochs = 1 + rng.below(4);
        let id = acai
            .engine
            .submit(JobSpec {
                project: ProjectId(project),
                user: UserId(project),
                name: format!("storm-{i}"),
                command: format!("python train_mnist.py --epoch {epochs}"),
                input_fileset: String::new(),
                output_fileset: format!("storm-{i}-out"),
                resources: ResourceConfig::new(1.0, 1024),
                pool: None,
                data_commit: None,
                priority,
                gang,
            })
            .unwrap();
        ids.push(id);
    }

    // First pump fills the empty cluster: weighted dominant shares of
    // the 16 tenants must be level within 10% (every backlog is deep).
    acai.engine.pump();
    let shares = acai.engine.scheduler.project_shares();
    assert_eq!(shares.len(), TENANTS as usize);
    let mean = shares.iter().map(|s| s.share).sum::<f64>() / shares.len() as f64;
    assert!(mean > 0.0);
    for s in &shares {
        assert!(
            (s.share - mean).abs() <= 0.1 * mean,
            "{}: weighted share {} strays >10% from level {mean}",
            s.project,
            s.share,
        );
    }

    acai.engine.run_until_idle();

    let counters = acai.engine.scheduler.counters();
    // de-O(n²): one pump never rescans the whole backlog more than the
    // enqueue/retire touches allow, and the storm's total decision
    // spend stays ~linear in jobs (a per-pump full rescan would burn
    // pumps × backlog ≈ hundreds of millions here).
    assert!(
        counters.max_pump_decisions < 2 * JOBS,
        "worst pump burned {} decisions",
        counters.max_pump_decisions
    );
    assert!(
        counters.decisions < 60 * JOBS,
        "storm burned {} total decisions",
        counters.decisions
    );
    assert!(counters.launched >= JOBS);

    let mut out = Vec::with_capacity(ids.len());
    for id in ids {
        let r = acai.engine.registry.get(id).unwrap();
        assert_eq!(r.state, JobState::Finished, "{id} did not finish: {:?}", r.error);
        // only low-priority work is ever evicted (spot is off here)
        if r.preemptions > 0 {
            assert_eq!(r.spec.priority, Priority::Low);
        }
        out.push((
            r.launched_at.unwrap().to_bits(),
            r.runtime_secs.unwrap().to_bits(),
            r.cost.unwrap().to_bits(),
            r.preemptions,
        ));
    }
    (out, counters)
}

/// (c) at the engine tier: same seed ⇒ bit-identical launch times,
/// runtimes, billed costs, and preemption counts for all 10k jobs.
#[test]
fn engine_storm_is_bit_identical_across_same_seed_reruns() {
    let (a, ca) = engine_storm(0xACA1_0001);
    let (b, cb) = engine_storm(0xACA1_0001);
    assert_eq!(a, b, "per-job (launched_at, runtime, cost, preemptions) diverged");
    assert_eq!(ca, cb, "scheduler counters diverged");
}

/// Weighted two-tenant workload through the in-process SDK client:
/// a 4:1 weight split yields a 4:1 slot split on a full cluster.
#[test]
fn weighted_two_tenant_workload_via_local_client() {
    let acai = Arc::new(
        Acai::boot(PlatformConfig {
            quota_k: 64,
            ..Default::default()
        })
        .unwrap(),
    );
    let root = acai.credentials.root_token().to_string();
    let (heavy_id, heavy_token) = acai
        .credentials
        .create_project(&root, "heavy", "alice")
        .unwrap();
    let (light_id, light_token) = acai
        .credentials
        .create_project(&root, "light", "bob")
        .unwrap();
    acai.set_project_weight(&root, "heavy", 4.0).unwrap();

    let heavy = Client::connect(acai.clone(), &heavy_token).unwrap();
    let light = Client::connect(acai.clone(), &light_token).unwrap();
    let request = |tenant: &str, i: usize| JobRequest {
        name: format!("{tenant}-{i}"),
        command: "python train_mnist.py --epoch 2".into(),
        input_fileset: String::new(),
        output_fileset: format!("{tenant}-{i}-out"),
        resources: ResourceConfig::new(4.0, 8192),
        pool: None,
        data_commit: None,
        priority: Priority::Normal,
        gang: 1,
    };
    let mut ids = Vec::new();
    for i in 0..40 {
        ids.push(heavy.submit(request("heavy", i)).unwrap());
        ids.push(light.submit(request("light", i)).unwrap());
    }

    // default cluster: 8 nodes × 16 vCPU = 32 four-vCPU slots; a 4:1
    // weight split over a deep backlog must fill 25–26 vs 6–7 slots.
    acai.engine.pump();
    let shares = acai.engine.scheduler.project_shares();
    let active = |id| {
        shares
            .iter()
            .find(|s| s.project == id)
            .map(|s| s.active)
            .unwrap_or(0) as f64
    };
    let (heavy_active, light_active) = (active(heavy_id), active(light_id));
    assert!(
        (heavy_active - 25.6).abs() <= 2.56,
        "heavy tenant holds {heavy_active} of 32 slots, expected 25.6 ±10%"
    );
    assert!(
        (light_active - 6.4).abs() <= 0.64 + 1.0,
        "light tenant holds {light_active} of 32 slots, expected 6.4 ±10% (±1 slot)"
    );

    heavy.wait_all();
    for id in ids {
        let r = acai.engine.registry.get(id).unwrap();
        assert_eq!(r.state, JobState::Finished);
        assert!(r.cost.unwrap() > 0.0);
    }
}

/// The same weighted workload over real HTTP: the weight endpoint,
/// priority/gang on the wire DTOs, and the `scheduler` metrics block.
#[test]
fn weighted_workload_and_scheduler_metrics_via_remote_client() {
    let acai = Arc::new(Acai::boot_default());
    let root = acai.credentials.root_token().to_string();
    let server = Server::serve(0, make_handler(acai.clone())).unwrap();
    let (_hp, heavy) =
        RemoteClient::create_project(server.addr(), &root, "heavy", "alice").unwrap();
    let (_lp, light) =
        RemoteClient::create_project(server.addr(), &root, "light", "bob").unwrap();

    // the weight endpoint is root-guarded and validated
    RemoteClient::set_project_weight(server.addr(), &root, "heavy", 4.0).unwrap();
    assert_eq!(
        RemoteClient::set_project_weight(server.addr(), "forged", "heavy", 2.0)
            .unwrap_err()
            .status(),
        403
    );
    assert_eq!(
        RemoteClient::set_project_weight(server.addr(), &root, "heavy", 0.0)
            .unwrap_err()
            .status(),
        400
    );
    assert_eq!(
        RemoteClient::set_project_weight(server.addr(), &root, "nosuch", 2.0)
            .unwrap_err()
            .status(),
        404
    );

    // priority + gang survive the wire round trip
    let request = |tenant: &str, i: usize, priority: Priority, gang: u32| JobRequest {
        name: format!("{tenant}-{i}"),
        command: "python train_mnist.py --epoch 1".into(),
        input_fileset: String::new(),
        output_fileset: format!("{tenant}-{i}-out"),
        resources: ResourceConfig::new(1.0, 1024),
        pool: None,
        data_commit: None,
        priority,
        gang,
    };
    let gang_job = heavy
        .submit_job(&request("heavy", 0, Priority::High, 2))
        .unwrap();
    let mut heavy_ids = Vec::new();
    let mut light_ids = Vec::new();
    for i in 1..8 {
        heavy_ids.push(heavy.submit_job(&request("heavy", i, Priority::Normal, 1)).unwrap());
        light_ids.push(light.submit_job(&request("light", i, Priority::Low, 1)).unwrap());
    }
    let status = heavy.await_job(gang_job).unwrap();
    assert_eq!(status.state, "finished");
    assert_eq!(status.priority, Priority::High);
    assert_eq!(status.gang, 2);
    assert!(status.cost.unwrap() > 0.0);
    for id in heavy_ids {
        assert_eq!(heavy.await_job(id).unwrap().state, "finished");
    }
    for id in light_ids {
        let status = light.await_job(id).unwrap();
        assert_eq!(status.state, "finished");
        assert_eq!(status.priority, Priority::Low);
    }

    // GET /v1/metrics serves the scheduler block with weighted shares
    let sched = heavy.scheduler_metrics().unwrap();
    assert!(sched.get("decisions").and_then(Json::as_u64).unwrap() >= 1);
    assert!(sched.get("launched").and_then(Json::as_u64).unwrap() >= 1);
    assert!(sched.get("max_pump_decisions").and_then(Json::as_u64).is_some());
    let projects = sched.get("projects").and_then(Json::as_array).unwrap();
    let heavy_weight = projects
        .iter()
        .find_map(|p| {
            let w = p.get("weight").and_then(Json::as_f64)?;
            (w == 4.0).then_some(w)
        });
    assert_eq!(heavy_weight, Some(4.0), "weight 4.0 missing from scheduler metrics");
}
