//! PJRT runtime integration: the AOT artifacts load, execute, and agree
//! with the pure-Rust reference paths.
//!
//! These tests need two things the default offline build doesn't have:
//! the AOT artifacts (`make artifacts`) and the real PJRT backend
//! (`--features pjrt`).  When either is missing, every test skips with a
//! note instead of failing — the pure-Rust fallback paths are covered by
//! the rest of the suite.

use acai::cluster::ResourceConfig;
use acai::profiler::{fit_native, CommandTemplate};
use acai::prng::Rng;
use acai::runtime::{MlpSession, Runtime, Tensor, FEATURES};
use acai::workload::synthetic_batch;

/// Load the runtime, or `None` when artifacts / the PJRT backend are
/// absent (offline build).
fn runtime() -> Option<Runtime> {
    let dir = acai::PlatformConfig::default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: no AOT artifacts under {dir:?} (run `make artifacts`)");
        return None;
    }
    match Runtime::load(&dir) {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("skipping: PJRT runtime unavailable ({e})");
            None
        }
    }
}

#[test]
fn manifest_constants_are_sane() {
    let Some(rt) = runtime() else { return };
    let c = rt.constants;
    assert_eq!(c.mlp_in, 784);
    assert_eq!(c.mlp_out, 10);
    assert!(c.fit_rows >= 135); // the paper's eval sweep must fit
    assert!(c.grid_rows >= 496); // the provisioning grid must fit
}

#[test]
fn loglinear_fit_matches_native_fit() {
    let Some(rt) = runtime() else { return };
    let template = CommandTemplate::parse("python t.py --epoch {1,2,3}").unwrap();
    let mut rows: Vec<[f64; FEATURES]> = Vec::new();
    let mut ys = Vec::new();
    for e in [1.0f64, 2.0, 3.0] {
        for c in [0.5f64, 1.0, 2.0] {
            for m in [512u32, 1024, 2048] {
                let res = ResourceConfig::new(c, m);
                rows.push(template.features(&[e], res));
                ys.push((6.63 * e * c.powf(-0.95) * (m as f64 / 1024.0).powf(-0.03)).ln());
            }
        }
    }
    let theta_pjrt = rt.loglinear_fit(&rows, &ys).unwrap();
    let theta_native = fit_native(&rows, &ys).unwrap();
    for (a, b) in theta_pjrt.iter().zip(theta_native.iter()) {
        assert!((a - b).abs() < 1e-3, "pjrt {theta_pjrt:?} native {theta_native:?}");
    }
    // and the exponents are the simulator's
    assert!((theta_pjrt[1] + 0.95).abs() < 1e-3);
    assert!((theta_pjrt[3] - 1.0).abs() < 1e-3);
}

#[test]
fn loglinear_predict_is_exp_of_dot() {
    let Some(rt) = runtime() else { return };
    let mut theta = [0.0f64; FEATURES];
    theta[0] = 2.0;
    theta[1] = -1.0;
    theta[3] = 1.0;
    let template = CommandTemplate::parse("python t.py --epoch {1,2}").unwrap();
    let rows: Vec<[f64; FEATURES]> = vec![
        template.features(&[20.0], ResourceConfig::new(2.0, 1024)),
        template.features(&[5.0], ResourceConfig::new(8.0, 512)),
    ];
    let got = rt.loglinear_predict(&theta, &rows).unwrap();
    for (g, row) in got.iter().zip(&rows) {
        let want: f64 = row
            .iter()
            .zip(theta.iter())
            .map(|(x, t)| x * t)
            .sum::<f64>()
            .exp();
        assert!((g - want).abs() / want < 1e-4, "{g} vs {want}");
    }
}

#[test]
fn mlp_training_reduces_loss_and_learns() {
    let Some(rt) = runtime() else { return };
    let mut session = MlpSession::new(&rt, 42);
    let mut rng = Rng::new(7);
    let (xe, ye) = synthetic_batch(&rt, &mut rng, rt.constants.eval_batch);
    let (loss0, acc0) = session.eval(xe.clone(), ye.clone()).unwrap();
    // untrained: chance-level accuracy, ~ln(10) loss
    assert!((loss0 - 10f32.ln()).abs() < 0.8, "loss0 {loss0}");
    assert!(acc0 < 0.35, "acc0 {acc0}");

    let mut first = None;
    let mut last = 0.0;
    for _ in 0..20 {
        let (x, y) = synthetic_batch(&rt, &mut rng, rt.constants.train_batch);
        last = session.train_step(x, y, 0.3).unwrap();
        first.get_or_insert(last);
    }
    assert!(last < first.unwrap() * 0.5, "{first:?} -> {last}");

    let (loss1, acc1) = session.eval(xe, ye).unwrap();
    assert!(loss1 < loss0 * 0.5);
    assert!(acc1 > acc0 + 0.4, "acc {acc0} -> {acc1}");
}

#[test]
fn mlp_serialization_has_all_parameters() {
    let Some(rt) = runtime() else { return };
    let session = MlpSession::new(&rt, 1);
    let bytes = session.serialize();
    let c = rt.constants;
    let expected = 4 * 4 // length headers
        + 4 * (c.mlp_in * c.mlp_hidden + c.mlp_hidden + c.mlp_hidden * c.mlp_out + c.mlp_out);
    assert_eq!(bytes.len(), expected);
}

#[test]
fn execute_rejects_shape_mismatches() {
    let Some(rt) = runtime() else { return };
    let err = rt
        .execute("loglinear_predict", &[Tensor::scalar(1.0), Tensor::scalar(2.0)])
        .unwrap_err();
    assert!(err.to_string().contains("shape"), "{err}");
    let err = rt.execute("nonexistent", &[]).unwrap_err();
    assert!(err.to_string().contains("unknown module"), "{err}");
}

#[test]
fn executions_counter_tracks_calls() {
    let Some(rt) = runtime() else { return };
    let before = rt.executions();
    let template = CommandTemplate::parse("python t.py --epoch {1,2}").unwrap();
    let rows = vec![template.features(&[1.0], ResourceConfig::new(1.0, 1024))];
    let theta = [0.1; FEATURES];
    rt.loglinear_predict(&theta, &rows).unwrap();
    assert_eq!(rt.executions(), before + 1);
}

#[test]
fn full_platform_with_runtime_profiles_via_pjrt() {
    // The end-to-end wiring: Acai boots with artifacts, the profiler's
    // fit + the provisioner's batch predict both run on PJRT.
    if runtime().is_none() {
        return;
    }
    let config = acai::PlatformConfig::with_artifacts(
        acai::PlatformConfig::default_artifacts_dir(),
    );
    let acai = acai::Acai::boot(config).unwrap();
    let p = acai::ids::ProjectId(1);
    let u = acai::ids::UserId(1);
    acai.datalake.storage.upload(p, &[("/d", b"x")]).unwrap();
    acai.datalake.filesets.create(p, "in", &["/d"], "u").unwrap();

    let execs_before = acai.runtime.as_ref().unwrap().executions();
    acai.profiler
        .profile("t", "python train_mnist.py --epoch {1,2,3}", p, u, "in")
        .unwrap();
    let fitted = acai.profiler.by_name("t").unwrap();
    let decision = acai
        .provisioner
        .optimize(
            &acai.profiler,
            &fitted,
            &[20.0],
            acai::autoprovision::Objective::MinCost { max_runtime: 1e6 },
        )
        .unwrap();
    assert!(decision.predicted_runtime > 0.0);
    // PJRT really ran: 27 MNIST jobs (train steps + eval) + 1 fit + 1 grid predict
    let execs = acai.runtime.as_ref().unwrap().executions() - execs_before;
    assert!(execs > 27, "only {execs} PJRT executions");
}
