//! Execution-engine integration: the full job flow of paper Figure 9 —
//! submit → queue → launch → run → upload → provenance + metadata,
//! plus quotas, kills, and multi-user fairness.

use acai::cluster::ResourceConfig;
use acai::datalake::metadata::ArtifactKind;
use acai::docstore::Clause;
use acai::engine::{JobSpec, JobState};
use acai::ids::{ProjectId, UserId};
use acai::json::Json;
use acai::{Acai, PlatformConfig};

const P: ProjectId = ProjectId(1);
const U: UserId = UserId(1);

fn platform() -> Acai {
    Acai::boot_default()
}

fn seed_input(acai: &Acai) {
    acai.datalake
        .storage
        .upload(P, &[("/data/train.bin", b"training-data")])
        .unwrap();
    acai.datalake
        .filesets
        .create(P, "mnist", &["/data/train.bin"], "alice")
        .unwrap();
}

fn job(name: &str, epochs: u32, res: ResourceConfig) -> JobSpec {
    JobSpec {
        project: P,
        user: U,
        name: name.into(),
        command: format!("python train_mnist.py --epoch {epochs}"),
        input_fileset: "mnist".into(),
        output_fileset: format!("{name}-out"),
        resources: res,
        pool: None,
        data_commit: None,
        priority: acai::engine::Priority::Normal,
        gang: 1,
    }
}

#[test]
fn full_job_flow_produces_outputs_provenance_and_metadata() {
    let acai = platform();
    seed_input(&acai);
    let id = acai
        .engine
        .submit(job("train", 5, ResourceConfig::new(2.0, 2048)))
        .unwrap();
    acai.engine.run_until_idle();

    let record = acai.engine.registry.get(id).unwrap();
    assert_eq!(record.state, JobState::Finished);
    let runtime = record.runtime_secs.unwrap();
    assert!(runtime > 10.0 && runtime < 25.0, "runtime {runtime}");
    assert!(record.cost.unwrap() > 0.0);

    // output file set exists and holds the model
    let out = acai
        .datalake
        .filesets
        .materialize(P, "train-out", None)
        .unwrap();
    assert!(out.iter().any(|(p, _)| p == "/model/mlp.bin"));

    // provenance edge: mnist:1 --job--> train-out:1
    let fwd = acai.datalake.provenance.forward(P, "mnist", 1);
    assert_eq!(fwd.len(), 1);
    assert_eq!(fwd[0].to, "train-out:1");
    assert_eq!(fwd[0].action, id.to_string());

    // log parser fed metadata: training_loss + runtime + cost on the job
    let doc = acai
        .datalake
        .metadata
        .get(P, ArtifactKind::Job, &id.to_string())
        .unwrap();
    assert!(doc.get("training_loss").and_then(Json::as_f64).is_some());
    assert!(doc.get("runtime_secs").and_then(Json::as_f64).is_some());
    assert_eq!(doc.get("state").and_then(Json::as_str), Some("finished"));
    // ...and on the output file set
    let fs_doc = acai
        .datalake
        .metadata
        .get(P, ArtifactKind::FileSet, "train-out:1")
        .unwrap();
    assert!(fs_doc.get("training_loss").and_then(Json::as_f64).is_some());

    // progress history followed Fig 9
    let stages: Vec<String> = acai
        .engine
        .monitor
        .history(id)
        .into_iter()
        .map(|p| p.stage)
        .collect();
    assert_eq!(
        stages,
        vec!["queued", "downloading", "running", "uploading", "finished"]
    );
}

#[test]
fn quota_k_limits_concurrency_per_user() {
    let config = PlatformConfig {
        quota_k: 2,
        ..Default::default()
    };
    let acai = Acai::boot(config).unwrap();
    seed_input(&acai);
    for i in 0..6 {
        acai.engine
            .submit(job(&format!("j{i}"), 10, ResourceConfig::new(0.5, 512)))
            .unwrap();
    }
    // after submission, exactly 2 running (quota), 4 queued
    assert_eq!(acai.cluster.running_count(), 2);
    assert_eq!(acai.engine.scheduler.queued((P, U)), 4);
    acai.engine.run_until_idle();
    let records = acai.engine.registry.list(P, Some(U));
    assert!(records.iter().all(|r| r.state == JobState::Finished));
}

#[test]
fn two_users_progress_independently() {
    let config = PlatformConfig {
        quota_k: 1,
        ..Default::default()
    };
    let acai = Acai::boot(config).unwrap();
    seed_input(&acai);
    let mut ids = vec![];
    for user in [UserId(1), UserId(2)] {
        for i in 0..3 {
            let mut spec = job(&format!("u{}-{i}", user.raw()), 4, ResourceConfig::new(0.5, 512));
            spec.user = user;
            ids.push(acai.engine.submit(spec).unwrap());
        }
    }
    // one job per user running despite quota 1
    assert_eq!(acai.cluster.running_count(), 2);
    acai.engine.run_until_idle();
    for id in ids {
        assert_eq!(acai.engine.registry.get(id).unwrap().state, JobState::Finished);
    }
}

#[test]
fn kill_queued_and_running_jobs() {
    let config = PlatformConfig {
        quota_k: 1,
        ..Default::default()
    };
    let acai = Acai::boot(config).unwrap();
    seed_input(&acai);
    let a = acai
        .engine
        .submit(job("a", 50, ResourceConfig::new(1.0, 1024)))
        .unwrap();
    let b = acai
        .engine
        .submit(job("b", 50, ResourceConfig::new(1.0, 1024)))
        .unwrap();
    // a running (quota 1), b queued
    acai.engine.kill(b).unwrap();
    assert_eq!(acai.engine.registry.get(b).unwrap().state, JobState::Killed);
    acai.engine.kill(a).unwrap();
    assert_eq!(acai.engine.registry.get(a).unwrap().state, JobState::Killed);
    assert_eq!(acai.cluster.running_count(), 0);
    // double-kill is a clean conflict
    assert_eq!(acai.engine.kill(a).unwrap_err().status(), 409);
}

#[test]
fn immutable_triplet_jobs_cannot_be_resubmitted() {
    // the registry assigns a fresh id per submission; the same spec
    // submitted twice is two jobs, each scheduled exactly once
    let acai = platform();
    seed_input(&acai);
    let a = acai
        .engine
        .submit(job("same", 2, ResourceConfig::new(0.5, 512)))
        .unwrap();
    let b = acai
        .engine
        .submit(job("same", 2, ResourceConfig::new(0.5, 512)))
        .unwrap();
    assert_ne!(a, b);
    acai.engine.run_until_idle();
    // two output versions of the same file set name
    assert_eq!(acai.datalake.filesets.latest_version(P, "same-out"), Some(2));
}

#[test]
fn submit_validates_resources_and_input() {
    let acai = platform();
    seed_input(&acai);
    let mut bad = job("x", 1, ResourceConfig::new(0.3, 512));
    assert_eq!(acai.engine.submit(bad.clone()).unwrap_err().status(), 400);
    bad.resources = ResourceConfig::new(1.0, 1024);
    bad.input_fileset = "no-such-set".into();
    assert_eq!(acai.engine.submit(bad.clone()).unwrap_err().status(), 404);
    bad.input_fileset = "mnist".into();
    bad.output_fileset = "".into();
    assert_eq!(acai.engine.submit(bad).unwrap_err().status(), 400);
}

#[test]
fn cluster_saturation_requeues_and_retries() {
    // a cluster with a single small node: jobs must take turns
    let mut config = PlatformConfig::default();
    config.cluster = acai::cluster::ClusterConfig::fixed(
        acai::cluster::NodeSpec::new(2.0, 2048),
        1,
    );
    config.quota_k = 8;
    let acai = Acai::boot(config).unwrap();
    seed_input(&acai);
    let mut ids = vec![];
    for i in 0..4 {
        ids.push(
            acai.engine
                .submit(job(&format!("s{i}"), 2, ResourceConfig::new(2.0, 2048)))
                .unwrap(),
        );
    }
    // only one fits at a time
    assert_eq!(acai.cluster.running_count(), 1);
    acai.engine.run_until_idle();
    for id in ids {
        assert_eq!(acai.engine.registry.get(id).unwrap().state, JobState::Finished);
    }
}

#[test]
fn one_saturated_pool_does_not_stall_other_pools() {
    use acai::cluster::{ClusterConfig, NodeSpec, PoolConfig};
    let mut config = PlatformConfig::default();
    config.cluster = ClusterConfig {
        pools: vec![
            PoolConfig::on_demand("small", NodeSpec::new(1.0, 1024), 1),
            PoolConfig::on_demand("big", NodeSpec::new(8.0, 8192), 1),
        ],
        ..Default::default()
    };
    let acai = Acai::boot(config).unwrap();
    seed_input(&acai);
    let pinned = |name: &str, pool: &str, vcpus: f64| {
        let mut spec = job(name, 20, ResourceConfig::new(vcpus, 1024));
        spec.pool = Some(pool.into());
        spec
    };
    // fill the small pool, then queue another job behind it
    let running_small = acai.engine.submit(pinned("s0", "small", 1.0)).unwrap();
    let blocked = acai.engine.submit(pinned("s1", "small", 1.0)).unwrap();
    // a job for the OTHER pool, submitted after the blocked one, must
    // still launch in the same pump round — per-pool saturation
    let big = acai.engine.submit(pinned("b0", "big", 2.0)).unwrap();
    assert_eq!(acai.engine.registry.get(running_small).unwrap().state, JobState::Running);
    assert_eq!(acai.engine.registry.get(blocked).unwrap().state, JobState::Queued);
    assert_eq!(acai.engine.registry.get(big).unwrap().state, JobState::Running);
    acai.engine.run_until_idle();
    for id in [running_small, blocked, big] {
        assert_eq!(acai.engine.registry.get(id).unwrap().state, JobState::Finished);
    }
}

#[test]
fn never_placeable_submissions_are_rejected_up_front() {
    use acai::cluster::{ClusterConfig, NodeSpec};
    let mut config = PlatformConfig::default();
    config.cluster = ClusterConfig::fixed(NodeSpec::new(4.0, 4096), 2);
    let acai = Acai::boot(config).unwrap();
    seed_input(&acai);
    // bigger than any node the cluster can ever own: 400 at submit,
    // not a forever-queued zombie
    let err = acai
        .engine
        .submit(job("huge", 1, ResourceConfig::new(8.0, 8192)))
        .unwrap_err();
    assert_eq!(err.status(), 400);
    // a same-shape job that fits is unaffected
    assert!(acai.engine.submit(job("ok", 1, ResourceConfig::new(4.0, 4096))).is_ok());
    acai.engine.run_until_idle();
}

#[test]
fn pool_reshape_under_a_queued_job_fails_it_loudly() {
    use acai::cluster::{ClusterConfig, NodeSpec, PoolConfig};
    let mut config = PlatformConfig::default();
    config.cluster = ClusterConfig {
        pools: vec![PoolConfig::on_demand("small", NodeSpec::new(8.0, 8192), 1)],
        ..Default::default()
    };
    let acai = Acai::boot(config).unwrap();
    seed_input(&acai);
    let pinned = |name: &str| {
        let mut spec = job(name, 10, ResourceConfig::new(8.0, 8192));
        spec.pool = Some("small".into());
        spec
    };
    // a fills the single node; b queues behind it
    let a = acai.engine.submit(pinned("a")).unwrap();
    let b = acai.engine.submit(pinned("b")).unwrap();
    assert_eq!(acai.engine.registry.get(b).unwrap().state, JobState::Queued);
    // reshape the pool's node spec below b's request while it is queued
    acai.cluster
        .set_pool(PoolConfig::on_demand("small", NodeSpec::new(4.0, 4096), 1))
        .unwrap();
    acai.engine.run_until_idle();
    // a (already placed on the old-shape node) drains normally; b can
    // never fit the new shape — failed loudly, not queued forever
    assert_eq!(acai.engine.registry.get(a).unwrap().state, JobState::Finished);
    let rb = acai.engine.registry.get(b).unwrap();
    assert_eq!(rb.state, JobState::Killed);
    assert!(
        rb.error.as_deref().unwrap_or("").contains("reshaped"),
        "{:?}",
        rb.error
    );
}

#[test]
fn metadata_arg_queries_find_jobs_by_epoch() {
    let acai = platform();
    seed_input(&acai);
    for epochs in [5, 10, 20] {
        acai.engine
            .submit(job(&format!("e{epochs}"), epochs, ResourceConfig::new(0.5, 512)))
            .unwrap();
    }
    acai.engine.run_until_idle();
    let hits = acai
        .datalake
        .metadata
        .query(P, ArtifactKind::Job, &[Clause::gte("arg_epoch", 10.0)])
        .unwrap();
    assert_eq!(hits.len(), 2);
}

#[test]
fn billing_uses_pricing_model_exactly() {
    let acai = platform();
    seed_input(&acai);
    let id = acai
        .engine
        .submit(job("b", 20, ResourceConfig::new(2.0, 7680)))
        .unwrap();
    acai.engine.run_until_idle();
    let record = acai.engine.registry.get(id).unwrap();
    let expect = acai
        .pricing
        .cost(record.spec.resources, record.runtime_secs.unwrap());
    assert!((record.cost.unwrap() - expect).abs() < 1e-12);
    // Table 2's baseline: ~64.6 s, ~$0.0977
    assert!((record.runtime_secs.unwrap() - 64.6).abs() < 2.0);
    assert!((record.cost.unwrap() - 0.09765).abs() < 0.004);
}
