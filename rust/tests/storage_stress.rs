//! Concurrency stress for the sharded storage substrate: 8 threads ×
//! 1k mixed put/get/rmw per store, driven purely through the [`Table`]
//! trait so every substrate (kvstore, docstore, objectstore, graphstore)
//! honors the same contract — no lost updates, and version counters
//! assign strictly sequential numbers under contention (the §4.4.3
//! guarantee the data lake builds on).

use std::collections::HashSet;
use std::sync::Arc;

use acai::bus::Bus;
use acai::docstore::DocStore;
use acai::graphstore::GraphStore;
use acai::json::Json;
use acai::kvstore::KvStore;
use acai::objectstore::ObjectStore;
use acai::simclock::SimClock;
use acai::storage::{bump_version, Rmw, SharedTable};

const THREADS: u64 = 8;
const OPS: u64 = 1_000;

fn all_stores() -> Vec<(&'static str, SharedTable)> {
    vec![
        ("kvstore", Arc::new(KvStore::in_memory()) as SharedTable),
        ("kvstore-1shard", Arc::new(KvStore::with_shards(1)) as SharedTable),
        ("docstore", Arc::new(DocStore::new()) as SharedTable),
        (
            "objectstore",
            Arc::new(ObjectStore::new(SimClock::new(), Bus::new())) as SharedTable,
        ),
        ("graphstore", Arc::new(GraphStore::new()) as SharedTable),
    ]
}

/// 8 threads × 1k ops: ¼ private puts, ¼ gets, ½ shared-counter RMWs.
fn hammer(label: &str, table: &SharedTable) {
    let mut handles = vec![];
    for t in 0..THREADS {
        let table = table.clone();
        handles.push(std::thread::spawn(move || {
            for i in 0..OPS {
                match i % 4 {
                    0 => {
                        table
                            .put("own", &format!("t{t}-{i:04}"), Json::from(i))
                            .unwrap();
                    }
                    1 => {
                        let _ = table.get("own", &format!("t{t}-{:04}", i - 1));
                    }
                    _ => {
                        table
                            .read_modify_write("ctr", "shared", &mut |cur| {
                                let v = cur.and_then(Json::as_u64).unwrap_or(0);
                                Ok(Rmw::Put(Json::from(v + 1)))
                            })
                            .unwrap();
                    }
                }
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    // no lost updates on the shared counter: half of all ops were RMWs
    let expected = THREADS * OPS / 2;
    assert_eq!(
        table.get("ctr", "shared").unwrap().as_u64(),
        Some(expected),
        "{label}: lost RMW updates"
    );
    // every thread-private put landed and scans see all of them
    for t in 0..THREADS {
        let mine = table.scan_prefix("own", &format!("t{t}-"));
        assert_eq!(mine.len() as u64, OPS / 4, "{label}: lost puts of thread {t}");
    }
    assert_eq!(table.count("own") as u64, THREADS * (OPS / 4), "{label}");
}

#[test]
fn mixed_workload_loses_nothing_on_any_substrate() {
    for (label, table) in all_stores() {
        hammer(label, &table);
    }
}

#[test]
fn version_numbers_are_sequential_under_contention() {
    for (label, table) in all_stores() {
        let per_thread = 125u32;
        let mut handles = vec![];
        for _ in 0..THREADS {
            let table = table.clone();
            handles.push(std::thread::spawn(move || {
                let mut got = Vec::with_capacity(per_thread as usize);
                for _ in 0..per_thread {
                    got.push(bump_version(table.as_ref(), "latest", "hot-path").unwrap());
                }
                got
            }));
        }
        let mut seen: Vec<u32> = Vec::new();
        for h in handles {
            let got = h.join().unwrap();
            // each thread observes strictly increasing versions
            assert!(
                got.windows(2).all(|w| w[0] < w[1]),
                "{label}: out-of-order versions within a thread"
            );
            seen.extend(got);
        }
        // globally: dense, unique 1..=N — no version ever lost or reused
        let unique: HashSet<u32> = seen.iter().copied().collect();
        assert_eq!(unique.len() as u64, THREADS * per_thread as u64, "{label}");
        assert_eq!(*seen.iter().max().unwrap() as u64, THREADS * per_thread as u64, "{label}");
        assert_eq!(*seen.iter().min().unwrap(), 1, "{label}");
    }
}

#[test]
fn concurrent_pipelines_assign_dense_file_versions() {
    // End-to-end: 8 "pipelines" upload the same path and create file
    // sets concurrently through the full datalake stack; version
    // assignment must stay dense and per-pipeline sequential.
    let acai = acai::Acai::boot_default();
    let project = acai::ids::ProjectId(1);
    let storage = acai.datalake.storage.clone();
    let mut handles = vec![];
    for _ in 0..THREADS {
        let storage = storage.clone();
        handles.push(std::thread::spawn(move || {
            let mut got = vec![];
            for _ in 0..25 {
                let v = storage.upload(project, &[("/stress/hot", b"x")]).unwrap();
                got.push(v[0].1);
            }
            got
        }));
    }
    let mut versions: Vec<u32> = Vec::new();
    for h in handles {
        let got = h.join().unwrap();
        assert!(got.windows(2).all(|w| w[0] < w[1]), "per-pipeline order");
        versions.extend(got);
    }
    versions.sort_unstable();
    let expected: Vec<u32> = (1..=(THREADS as u32 * 25)).collect();
    assert_eq!(versions, expected, "file versions must be dense and unique");

    // file-set versions ride the same guarantee
    let filesets = acai.datalake.filesets.clone();
    let mut handles = vec![];
    for _ in 0..THREADS {
        let filesets = filesets.clone();
        handles.push(std::thread::spawn(move || {
            let mut got = vec![];
            for _ in 0..10 {
                got.push(
                    filesets
                        .create(project, "stress-set", &["/stress/hot#1"], "stress")
                        .unwrap(),
                );
            }
            got
        }));
    }
    let mut set_versions: Vec<u32> = Vec::new();
    for h in handles {
        set_versions.extend(h.join().unwrap());
    }
    set_versions.sort_unstable();
    let expected: Vec<u32> = (1..=(THREADS as u32 * 10)).collect();
    assert_eq!(set_versions, expected, "file-set versions must be dense");
}
