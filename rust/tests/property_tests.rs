//! Property tests (via the in-crate testkit mini-framework) over the
//! coordinator's core invariants: scheduling fairness, version
//! monotonicity, file-set resolution, index consistency, DAG acyclicity,
//! JSON round-tripping, and pricing monotonicity.

use acai::cluster::ResourceConfig;
use acai::docstore::{Clause, DocStore};
use acai::engine::{JobSpec, JobState, Scheduler};
use acai::graphstore::GraphStore;
use acai::ids::{JobId, ProjectId, UserId};
use acai::json::Json;
use acai::pricing::PricingModel;
use acai::testkit::property;
use acai::{Acai, PlatformConfig};

#[test]
fn prop_scheduler_never_exceeds_quota_and_preserves_fifo() {
    property("scheduler invariants", 60, |g| {
        let quota = g.usize(1..5);
        let scheduler = Scheduler::new(quota);
        let users = g.usize(1..4);
        let mut queued: Vec<Vec<u64>> = vec![vec![]; users];
        let mut next_id = 1u64;

        // interleave random enqueues / launches / completions
        let mut active: Vec<Vec<u64>> = vec![vec![]; users];
        let mut launched_order: Vec<Vec<u64>> = vec![vec![]; users];
        for _ in 0..g.usize(10..60) {
            match g.usize(0..3) {
                0 => {
                    let u = g.usize(0..users);
                    let key = (ProjectId(1), UserId(u as u64));
                    scheduler.enqueue(key, JobId(next_id));
                    queued[u].push(next_id);
                    next_id += 1;
                }
                1 => {
                    for (key, job) in scheduler.launchable() {
                        let u = key.1.raw() as usize;
                        active[u].push(job.raw());
                        launched_order[u].push(job.raw());
                        let pos = queued[u].iter().position(|j| *j == job.raw()).unwrap();
                        queued[u].remove(pos);
                        // INVARIANT: quota respected at every instant
                        assert!(active[u].len() <= quota, "quota violated");
                    }
                }
                _ => {
                    let u = g.usize(0..users);
                    if let Some(job) = active[u].pop() {
                        scheduler.on_terminal((ProjectId(1), UserId(u as u64)), JobId(job));
                    }
                }
            }
        }
        // INVARIANT: per-user launch order is FIFO (ids are monotone
        // within a user because we enqueue monotonically)
        for order in &launched_order {
            let mut sorted = order.clone();
            sorted.sort();
            assert_eq!(*order, sorted, "FIFO violated");
        }
    });
}

#[test]
fn prop_file_versions_are_dense_and_monotone() {
    property("version monotonicity", 30, |g| {
        let acai = Acai::boot_default();
        let p = ProjectId(1);
        let paths: Vec<String> = (0..g.usize(1..4)).map(|i| format!("/f{i}")).collect();
        let mut counts = vec![0u32; paths.len()];
        for _ in 0..g.usize(1..30) {
            let i = g.usize(0..paths.len());
            let versions = acai
                .datalake
                .storage
                .upload(p, &[(paths[i].as_str(), b"x")])
                .unwrap();
            counts[i] += 1;
            // INVARIANT: version assigned == count of uploads so far
            assert_eq!(versions[0].1, counts[i]);
        }
        for (path, count) in paths.iter().zip(&counts) {
            let versions = acai.datalake.storage.versions(p, path);
            assert_eq!(versions, (1..=*count).collect::<Vec<u32>>());
        }
    });
}

#[test]
fn prop_fileset_resolution_is_deterministic_and_single_version_per_path() {
    property("fileset resolution", 30, |g| {
        let acai = Acai::boot_default();
        let p = ProjectId(1);
        let n_files = g.usize(1..6);
        let paths: Vec<String> = (0..n_files).map(|i| format!("/data/f{i}")).collect();
        for path in &paths {
            for _ in 0..g.usize(1..4) {
                acai.datalake.storage.upload(p, &[(path.as_str(), b"x")]).unwrap();
            }
        }
        // random specs: mix of plain paths and versioned ones
        let mut specs: Vec<String> = vec![];
        for _ in 0..g.usize(1..8) {
            let path = &paths[g.usize(0..paths.len())];
            let versions = acai.datalake.storage.versions(p, path);
            if g.bool(0.5) {
                specs.push(path.clone());
            } else {
                let v = versions[g.usize(0..versions.len())];
                specs.push(format!("{path}#{v}"));
            }
        }
        let refs: Vec<&str> = specs.iter().map(|s| s.as_str()).collect();
        let r1 = acai.datalake.filesets.resolve(p, &refs).unwrap();
        let r2 = acai.datalake.filesets.resolve(p, &refs).unwrap();
        // INVARIANT: deterministic
        assert_eq!(r1, r2);
        // INVARIANT: one version per path
        let mut seen = std::collections::HashSet::new();
        for (path, _) in &r1.entries {
            assert!(seen.insert(path.clone()), "duplicate path {path}");
        }
    });
}

#[test]
fn prop_docstore_queries_match_linear_scan() {
    property("docstore index consistency", 40, |g| {
        let ds = DocStore::new();
        let n = g.usize(1..40);
        let mut docs = Vec::new();
        for i in 0..n {
            let v = g.f64(0.0, 1.0);
            let cat = *g.pick(&["a", "b", "c"]);
            ds.put(
                "c",
                &format!("doc-{i:04}"),
                Json::obj().field("v", v).field("cat", cat).build(),
            );
            docs.push((format!("doc-{i:04}"), v, cat));
        }
        let lo = g.f64(0.0, 1.0);
        let hi = g.f64(lo, 1.0);
        let cat = *g.pick(&["a", "b", "c"]);
        let hits = ds
            .find("c", &[Clause::eq("cat", cat), Clause::gte("v", lo), Clause::lte("v", hi)])
            .unwrap();
        let expected: Vec<String> = docs
            .iter()
            .filter(|(_, v, c)| *c == cat && *v >= lo && *v <= hi)
            .map(|(id, _, _)| id.clone())
            .collect();
        let got: Vec<String> = hits.into_iter().map(|(id, _)| id).collect();
        assert_eq!(got, expected);
    });
}

#[test]
fn prop_random_dags_stay_acyclic() {
    property("graph acyclicity", 40, |g| {
        let graph = GraphStore::new();
        let nodes = g.usize(2..12);
        for _ in 0..g.usize(1..40) {
            let a = g.usize(0..nodes);
            let b = g.usize(0..nodes);
            let _ = graph.add_edge(
                &format!("n{a}"),
                &format!("n{b}"),
                "e",
                "job_execution",
            ); // may reject; that's the point
        }
        // INVARIANT: topo order covers every node exactly once
        let (all_nodes, edges) = graph.whole_graph();
        let order = graph.topo_order();
        assert_eq!(order.len(), all_nodes.len());
        let pos: std::collections::HashMap<&str, usize> = order
            .iter()
            .enumerate()
            .map(|(i, n)| (n.as_str(), i))
            .collect();
        for e in &edges {
            assert!(pos[e.from.as_str()] < pos[e.to.as_str()], "edge against topo order");
        }
    });
}

#[test]
fn prop_json_encode_parse_round_trip() {
    property("json round trip", 80, |g| {
        fn gen_value(g: &mut acai::testkit::Gen, depth: usize) -> Json {
            match if depth == 0 { g.usize(0..4) } else { g.usize(0..6) } {
                0 => Json::Null,
                1 => Json::Bool(g.bool(0.5)),
                2 => Json::Num((g.f64(-1e6, 1e6) * 1000.0).round() / 1000.0),
                3 => Json::Str(g.ident(12)),
                4 => {
                    let n = g.usize(0..4);
                    Json::Arr((0..n).map(|_| gen_value(g, depth - 1)).collect())
                }
                _ => {
                    let n = g.usize(0..4);
                    let mut b = Json::obj();
                    for _ in 0..n {
                        let key = g.ident(8);
                        b = b.field(key, gen_value(g, depth - 1));
                    }
                    b.build()
                }
            }
        }
        let v = gen_value(g, 3);
        let parsed = acai::json::parse(&v.encode()).unwrap();
        assert_eq!(parsed, v);
    });
}

#[test]
fn prop_pricing_is_monotone_in_resources_and_time() {
    property("pricing monotonicity", 60, |g| {
        let p = PricingModel::default();
        let c1 = g.usize(1..16) as f64 * 0.5;
        let c2 = c1 + 0.5;
        let m1 = (g.usize(2..32) * 256) as u32;
        let m2 = m1 + 256;
        let t = g.f64(1.0, 10_000.0);
        assert!(
            p.cost(ResourceConfig::new(c2, m1), t) > p.cost(ResourceConfig::new(c1, m1), t)
        );
        assert!(
            p.cost(ResourceConfig::new(c1, m2), t) > p.cost(ResourceConfig::new(c1, m1), t)
        );
        assert!(p.cost(ResourceConfig::new(c1, m1), t * 2.0) > p.cost(ResourceConfig::new(c1, m1), t));
    });
}

#[test]
fn prop_engine_batches_always_terminate_with_conserved_billing() {
    property("engine batch conservation", 10, |g| {
        let config = PlatformConfig {
            quota_k: g.usize(1..5),
            ..Default::default()
        };
        let acai = Acai::boot(config).unwrap();
        let p = ProjectId(1);
        acai.datalake.storage.upload(p, &[("/d", b"x")]).unwrap();
        acai.datalake.filesets.create(p, "in", &["/d"], "u").unwrap();
        let n = g.usize(1..12);
        let mut ids = vec![];
        for i in 0..n {
            let epochs = g.usize(1..6) as u32;
            ids.push(
                acai.engine
                    .submit(JobSpec {
                        project: p,
                        user: UserId(g.usize(1..3) as u64),
                        name: format!("j{i}"),
                        command: format!("python train_mnist.py --epoch {epochs}"),
                        input_fileset: "in".into(),
                        output_fileset: format!("o{i}"),
                        resources: ResourceConfig::new(
                            g.usize(1..16) as f64 * 0.5,
                            (g.usize(2..32) * 256) as u32,
                        ),
                        pool: None,
                        data_commit: None,
                        priority: acai::engine::Priority::Normal,
                        gang: 1,
                    })
                    .unwrap(),
            );
        }
        acai.engine.run_until_idle();
        for id in ids {
            let r = acai.engine.registry.get(id).unwrap();
            // INVARIANT: terminal, billed consistently with the pricing model
            assert_eq!(r.state, JobState::Finished);
            let expect = acai.pricing.cost(r.spec.resources, r.runtime_secs.unwrap());
            assert!((r.cost.unwrap() - expect).abs() < 1e-9);
        }
        // INVARIANT: all cluster resources returned
        let (used, _, used_mem, _) = acai.cluster.utilization();
        assert_eq!((used, used_mem), (0, 0));
    });
}

#[test]
fn prop_upload_sessions_serialize_versions_under_chaos() {
    // The §4.4.3 guarantees under random interleavings of successful
    // uploads, injected failures, aborts, and resumes: versions stay
    // dense and sequential, committed content is never lost, and no
    // aborted bytes leak into the version history.
    use acai::datalake::SessionState;
    property("upload session chaos", 25, |g| {
        let acai = Acai::boot_default();
        let storage = acai.datalake.storage.clone();
        let objects = acai.object_store();
        let p = ProjectId(1);
        let mut committed: Vec<String> = vec![]; // content per version, in order
        for round in 0..g.usize(1..25) {
            let content = format!("round-{round}");
            match g.usize(0..4) {
                0 => {
                    // clean upload
                    storage.upload(p, &[("/f", content.as_bytes())]).unwrap();
                    committed.push(content);
                }
                1 => {
                    // failed upload then abort
                    objects.inject_put_failures(1);
                    let (id, grants) = storage.start_session(p, &["/f"]).unwrap();
                    assert!(objects
                        .put_presigned(&grants[0].1.token, content.clone().into_bytes())
                        .is_err());
                    storage.abort_session(id).unwrap();
                }
                2 => {
                    // failed upload, resume, then succeed
                    objects.inject_put_failures(1);
                    let (id, grants) = storage.start_session(p, &["/f"]).unwrap();
                    let _ = objects.put_presigned(&grants[0].1.token, content.clone().into_bytes());
                    let again = storage.resume_session(id).unwrap();
                    objects
                        .put_presigned(&again[0].1.token, content.clone().into_bytes())
                        .unwrap();
                    assert!(matches!(
                        storage.poll_session(id).unwrap(),
                        SessionState::Committed(_)
                    ));
                    committed.push(content);
                }
                _ => {
                    // abandoned pending session, later aborted
                    let (id, _grants) = storage.start_session(p, &["/f"]).unwrap();
                    storage.abort_session(id).unwrap();
                }
            }
        }
        // INVARIANT: dense versions, one per committed upload, in order
        let versions = storage.versions(p, "/f");
        assert_eq!(versions.len(), committed.len());
        assert_eq!(versions, (1..=committed.len() as u32).collect::<Vec<_>>());
        for (v, content) in versions.iter().zip(&committed) {
            assert_eq!(
                storage.read(p, "/f", Some(*v)).unwrap(),
                content.as_bytes(),
                "version {v} content corrupted"
            );
        }
    });
}

#[test]
fn prop_fileset_cache_is_transparent_and_bounded() {
    // The inter-job cache must be invisible to correctness (same bytes
    // with or without a hit) and never exceed its budget.
    property("cache transparency", 20, |g| {
        let acai = Acai::boot_default();
        let p = ProjectId(1);
        let n_sets = g.usize(1..5);
        for i in 0..n_sets {
            let content: Vec<u8> = vec![i as u8; g.usize(1..2000)];
            let path = format!("/f{i}");
            acai.datalake
                .storage
                .upload(p, &[(path.as_str(), &content)])
                .unwrap();
            acai.datalake
                .filesets
                .create(p, &format!("s{i}"), &[path.as_str()], "u")
                .unwrap();
        }
        for _ in 0..g.usize(1..30) {
            let i = g.usize(0..n_sets);
            let via_cache = acai
                .datalake
                .materialize_cached(p, &format!("s{i}"), None)
                .unwrap();
            let direct = acai
                .datalake
                .filesets
                .materialize(p, &format!("s{i}"), None)
                .unwrap();
            assert_eq!(via_cache.len(), direct.len());
            for ((pa, ba), (pb, bb)) in via_cache.iter().zip(&direct) {
                assert_eq!(pa, pb);
                assert_eq!(ba, bb);
            }
            let (_, _, bytes) = acai.datalake.cache.stats();
            assert!(bytes <= acai.datalake.cache.capacity);
        }
        let (hits, misses, _) = acai.datalake.cache.stats();
        assert!(hits + misses > 0);
    });
}

#[test]
fn prop_log_parser_never_panics_and_tags_are_well_formed() {
    use acai::engine::logserver::parse_tag;
    property("log parser fuzz", 100, |g| {
        // random line soup, sometimes tag-shaped
        let line = match g.usize(0..4) {
            0 => format!("[[acai]] {}={}", g.ident(8), g.f64(-1e9, 1e9)),
            1 => format!("[[acai]] {}={}", g.ident(8), g.ident(12)),
            2 => format!("[[acai]]{}", g.ident(20)),
            _ => g.ident(30),
        };
        if let Some((key, value)) = parse_tag(&line) {
            assert!(!key.is_empty());
            assert!(!key.contains(char::is_whitespace));
            match value {
                Json::Num(n) => assert!(n.is_finite()),
                Json::Str(_) => {}
                other => panic!("unexpected tag value {other:?}"),
            }
        }
    });
}

#[test]
fn prop_chunker_split_join_is_identity_and_deterministic() {
    use acai::datalake::cas::chunk_len;
    property("cas chunker", 25, |g| {
        let acai = Acai::boot_default();
        let cas = acai.datalake.cas.clone();
        // spans empty, sub-chunk, exact-multiple, and multi-chunk sizes
        let n = g.usize(0..200_000);
        let bytes: Vec<u8> = (0..n).map(|_| g.usize(0..256) as u8).collect();
        let m1 = cas.ingest(&bytes).unwrap();
        let m2 = cas.ingest(&bytes).unwrap();
        // INVARIANT: identical content => identical chunk ids
        assert_eq!(m1, m2);
        // INVARIANT: split -> join is the identity
        assert_eq!(cas.materialize(&m1).unwrap(), bytes);
        // INVARIANT: manifest lengths partition the payload exactly
        assert_eq!(m1.iter().map(|id| chunk_len(id)).sum::<u64>(), n as u64);
        assert_eq!(m1.len(), n.div_ceil(cas.chunk_size()));
        // INVARIANT: a ranged join agrees with slicing the original
        if n > 0 {
            let off = g.usize(0..n);
            let len = g.usize(0..n - off + 1);
            assert_eq!(
                cas.materialize_range(&m1, off as u64, len as u64).unwrap(),
                &bytes[off..off + len]
            );
        }
    });
}

/// Drive a random upload / overwrite / delete sequence over a small
/// path set (the shared setup for the time-travel properties below).
fn churn_lake(g: &mut acai::testkit::Gen, acai: &Acai, p: ProjectId, rounds: usize) {
    let paths = ["/tt/a", "/tt/b", "/tt/c", "/tt/d"];
    for round in 0..rounds {
        let path = *g.pick(&paths);
        match g.usize(0..3) {
            0 => {
                // fresh content (length and bytes vary per round)
                let content: Vec<u8> = (0..g.usize(1..500)).map(|i| (round + i) as u8).collect();
                acai.datalake.storage.upload(p, &[(path, &content)]).unwrap();
            }
            1 => {
                // duplicate content: exercises chunk sharing across rows
                acai.datalake.storage.upload(p, &[(path, b"common-payload")]).unwrap();
            }
            _ => {
                // delete a random live version, if any
                let versions = acai.datalake.storage.versions(p, path);
                if !versions.is_empty() {
                    let v = versions[g.usize(0..versions.len())];
                    acai.datalake.storage.delete_version(p, path, v).unwrap();
                }
            }
        }
    }
}

#[test]
fn prop_commits_of_an_unchanged_lake_are_identical() {
    property("commit determinism", 25, |g| {
        let acai = Acai::boot_default();
        let p = ProjectId(1);
        let rounds = g.usize(1..30);
        churn_lake(g, &acai, p, rounds);
        let tt = &acai.datalake.timetravel;
        // INVARIANT: committing twice with no writes in between captures
        // the same file table (ids and timestamps aside)
        let c1 = tt.commit(p, "first").unwrap();
        let c2 = tt.commit(p, "second").unwrap();
        assert_eq!(c1.files, c2.files, "snapshot table must be deterministic");
        assert_eq!(c1.bytes(), c2.bytes());
        assert!(tt.diff(p, c1.id, c2.id).unwrap().is_empty());
    });
}

#[test]
fn prop_diff_of_a_commit_with_itself_is_empty() {
    property("diff identity", 25, |g| {
        let acai = Acai::boot_default();
        let p = ProjectId(1);
        let rounds = g.usize(1..30);
        churn_lake(g, &acai, p, rounds);
        let c = acai.datalake.timetravel.commit(p, "self").unwrap();
        // INVARIANT: diff(c, c) reports no drift, ever
        let d = acai.datalake.timetravel.diff(p, c.id, c.id).unwrap();
        assert!(d.is_empty(), "self-diff must be empty: {d:?}");
    });
}

#[test]
fn prop_diff_is_symmetric_under_side_swap() {
    property("diff symmetry", 25, |g| {
        let acai = Acai::boot_default();
        let p = ProjectId(1);
        let rounds = g.usize(1..25);
        churn_lake(g, &acai, p, rounds);
        let a = acai.datalake.timetravel.commit(p, "a").unwrap();
        let rounds = g.usize(1..25);
        churn_lake(g, &acai, p, rounds);
        let b = acai.datalake.timetravel.commit(p, "b").unwrap();
        let fwd = acai.datalake.timetravel.diff(p, a.id, b.id).unwrap();
        let rev = acai.datalake.timetravel.diff(p, b.id, a.id).unwrap();
        // INVARIANT: swapping sides swaps added <-> removed exactly
        assert_eq!(fwd.added, rev.removed);
        assert_eq!(fwd.removed, rev.added);
        // INVARIANT: each changed entry mirrors its byte/chunk columns
        assert_eq!(fwd.changed.len(), rev.changed.len());
        for (f, r) in fwd.changed.iter().zip(&rev.changed) {
            assert_eq!(f.path, r.path);
            assert_eq!(f.bytes_added, r.bytes_removed);
            assert_eq!(f.bytes_removed, r.bytes_added);
            assert_eq!(f.chunks_added, r.chunks_removed);
            assert_eq!(f.chunks_removed, r.chunks_added);
            assert_eq!(f.changed_bytes(), r.changed_bytes());
        }
    });
}

#[test]
fn prop_dedup_reupload_stores_less_than_double() {
    property("cas dedup on re-upload", 10, |g| {
        let acai = Acai::boot_default();
        let p = ProjectId(1);
        let chunk = acai.datalake.cas.chunk_size();
        // a dataset of several chunks, then an appended v2 sharing >=90%
        let n = g.usize(3 * chunk..8 * chunk);
        let v1: Vec<u8> = (0..n).map(|_| g.usize(0..256) as u8).collect();
        acai.datalake.storage.upload(p, &[("/ds", &v1)]).unwrap();
        let mut v2 = v1.clone();
        v2.extend((0..g.usize(1..chunk / 2)).map(|_| g.usize(0..256) as u8));
        acai.datalake.storage.upload(p, &[("/ds", &v2)]).unwrap();
        let stats = acai.datalake.cas.stats();
        // INVARIANT: two versions sharing almost everything store far
        // less than two full copies
        assert!(
            stats.stored_bytes < 2 * v1.len() as u64,
            "stored {} vs logical-per-version {}",
            stats.stored_bytes,
            v1.len()
        );
        // every aligned shared chunk deduped
        assert!(stats.dedup_hits >= (v1.len() / chunk) as u64);
        // INVARIANT: dedup is invisible to reads
        assert_eq!(acai.datalake.storage.read(p, "/ds", Some(1)).unwrap(), v1);
        assert_eq!(acai.datalake.storage.read(p, "/ds", Some(2)).unwrap(), v2);
    });
}

#[test]
fn prop_dominant_share_drain_order_matches_model() {
    use acai::engine::{Demand, Priority};
    use std::collections::VecDeque;
    // The scheduler's weighted-DRF drain must agree, decision for
    // decision, with an independent greedy model: always the project
    // with the smallest dominant share / weight, ties broken by project
    // id.  Sequence equality across random enqueue/retire interleavings
    // proves the ordering is total (never panics, never skips) and
    // stable (deterministic tie-break).
    property("weighted DRF drain order", 40, |g| {
        let scheduler = Scheduler::new(1000); // quota never binds here
        let total_milli = g.u64(8..65) * 1000;
        let total_mem = g.u64(8..65) * 1024;
        scheduler.set_capacity(total_milli, total_mem);
        let nprojects = g.usize(2..6);
        let mut weights = Vec::new();
        for p in 0..nprojects {
            let w = g.usize(1..9) as f64;
            scheduler.set_weight(ProjectId(p as u64 + 1), w).unwrap();
            weights.push(w);
        }
        let mut queues: Vec<VecDeque<(u64, Demand)>> = vec![VecDeque::new(); nprojects];
        let mut used = vec![(0u64, 0u64); nprojects];
        let mut live: Vec<(usize, u64, Demand)> = Vec::new();
        let mut next_id = 1u64;
        for _round in 0..g.usize(2..6) {
            for _ in 0..g.usize(1..12) {
                let p = g.usize(0..nprojects);
                let d = Demand {
                    milli_vcpus: g.u64(1..9) * 250,
                    mem_mb: g.u64(1..9) * 256,
                };
                scheduler.enqueue_job(
                    (ProjectId(p as u64 + 1), UserId(1)),
                    JobId(next_id),
                    d,
                    Priority::Normal,
                );
                queues[p].push_back((next_id, d));
                next_id += 1;
            }
            // the model's full greedy drain
            let mut expect = Vec::new();
            loop {
                let candidates: Vec<usize> =
                    (0..nprojects).filter(|&p| !queues[p].is_empty()).collect();
                let Some(&p) = candidates.iter().min_by_key(|&&p| {
                    let cpu = used[p].0 as f64 / total_milli.max(1) as f64;
                    let mem = used[p].1 as f64 / total_mem.max(1) as f64;
                    let share = cpu.max(mem) / weights[p];
                    assert!(share.is_finite() && share >= 0.0, "share not totally ordered");
                    (share.to_bits(), p)
                }) else {
                    break;
                };
                let (job, d) = queues[p].pop_front().unwrap();
                used[p].0 += d.milli_vcpus;
                used[p].1 += d.mem_mb;
                live.push((p, job, d));
                expect.push((p, job));
            }
            let got: Vec<(usize, u64)> = scheduler
                .launchable()
                .into_iter()
                .map(|((pid, _), job)| (pid.raw() as usize - 1, job.raw()))
                .collect();
            assert_eq!(got, expect, "drain order diverged from the DRF model");
            // retire a random subset, releasing the charged demand
            for _ in 0..g.usize(0..live.len() + 1) {
                let (p, job, d) = live.swap_remove(g.usize(0..live.len()));
                scheduler.on_terminal((ProjectId(p as u64 + 1), UserId(1)), JobId(job));
                used[p].0 -= d.milli_vcpus;
                used[p].1 -= d.mem_mb;
            }
        }
        // the published shares agree bit-for-bit with the model
        for s in scheduler.project_shares() {
            let p = s.project.raw() as usize - 1;
            let cpu = used[p].0 as f64 / total_milli.max(1) as f64;
            let mem = used[p].1 as f64 / total_mem.max(1) as f64;
            assert_eq!(s.share.to_bits(), (cpu.max(mem) / weights[p]).to_bits());
        }
    });
}

#[test]
fn prop_gang_placement_is_all_or_nothing_at_every_step() {
    use acai::cluster::{ClusterConfig, NodeSpec};
    use acai::engine::Priority;
    // At every observable engine step a gang job holds either all of its
    // slots (Running, one container per replica) or none (Queued): a
    // partially-placeable gang must never camp on capacity.
    property("gang all-or-nothing", 12, |g| {
        let nodes = g.usize(1..4);
        let config = PlatformConfig {
            cluster: ClusterConfig::fixed(NodeSpec::new(4.0, 4096), nodes),
            quota_k: g.usize(2..6),
            ..Default::default()
        };
        let acai = Acai::boot(config).unwrap();
        let p = ProjectId(1);
        acai.datalake.storage.upload(p, &[("/d", b"x")]).unwrap();
        acai.datalake.filesets.create(p, "in", &["/d"], "u").unwrap();
        // 4-vCPU nodes, 1-vCPU replicas: 4 slots per node
        let max_gang = (nodes * 4).min(5);
        let mut ids = Vec::new();
        for i in 0..g.usize(3..12) {
            let gang = g.usize(1..max_gang + 1) as u32;
            ids.push(
                acai.engine
                    .submit(JobSpec {
                        project: p,
                        user: UserId(g.usize(1..3) as u64),
                        name: format!("g{i}"),
                        command: format!("python train_mnist.py --epoch {}", g.usize(1..4)),
                        input_fileset: "in".into(),
                        output_fileset: format!("o{i}"),
                        resources: ResourceConfig::new(1.0, 512),
                        pool: None,
                        data_commit: None,
                        priority: Priority::Normal,
                        gang,
                    })
                    .unwrap(),
            );
        }
        let check = |msg: &str| {
            for &id in &ids {
                let r = acai.engine.registry.get(id).unwrap();
                match r.state {
                    JobState::Running => assert_eq!(
                        r.containers.len(),
                        r.spec.gang as usize,
                        "{msg}: running gang holds a partial reservation"
                    ),
                    JobState::Queued => assert!(
                        r.containers.is_empty(),
                        "{msg}: queued gang holds slots"
                    ),
                    _ => {}
                }
            }
        };
        acai.engine.pump();
        check("after first pump");
        let mut steps = 0;
        while acai.engine.step() {
            check("after step");
            steps += 1;
            assert!(steps < 10_000, "engine livelock");
        }
        for id in ids {
            assert_eq!(acai.engine.registry.get(id).unwrap().state, JobState::Finished);
        }
        // INVARIANT: no reservation leaked through rollbacks
        let (used, _, used_mem, _) = acai.cluster.utilization();
        assert_eq!((used, used_mem), (0, 0), "leaked gang reservation");
    });
}

#[test]
fn prop_priority_eviction_never_touches_equal_or_higher() {
    use acai::cluster::{ClusterConfig, NodeSpec};
    use acai::engine::Priority;
    // On a cluster with no spot pools the only preemption source is
    // priority eviction — so every job that records a preemption must be
    // Low priority, and the scheduler's eviction counter must account
    // for every one of them.
    property("preemption priority ladder", 12, |g| {
        let config = PlatformConfig {
            cluster: ClusterConfig::fixed(NodeSpec::new(8.0, 8192), g.usize(1..3)),
            quota_k: 8,
            ..Default::default()
        };
        let acai = Acai::boot(config).unwrap();
        for pr in 1..=2u64 {
            let p = ProjectId(pr);
            acai.datalake.storage.upload(p, &[("/d", b"x")]).unwrap();
            acai.datalake.filesets.create(p, "in", &["/d"], "u").unwrap();
        }
        let prios = [Priority::Low, Priority::Normal, Priority::High];
        let mut ids = Vec::new();
        for i in 0..g.usize(6..20) {
            ids.push(
                acai.engine
                    .submit(JobSpec {
                        project: ProjectId(g.usize(1..3) as u64),
                        user: UserId(g.usize(1..3) as u64),
                        name: format!("p{i}"),
                        command: format!("python train_mnist.py --epoch {}", g.usize(1..5)),
                        input_fileset: "in".into(),
                        output_fileset: format!("o{i}"),
                        resources: ResourceConfig::new(g.usize(1..5) as f64, 1024),
                        pool: None,
                        data_commit: None,
                        priority: *g.pick(&prios),
                        gang: g.usize(1..3) as u32,
                    })
                    .unwrap(),
            );
        }
        acai.engine.run_until_idle();
        let mut preempted_total = 0u64;
        for id in ids {
            let r = acai.engine.registry.get(id).unwrap();
            assert_eq!(r.state, JobState::Finished);
            if r.preemptions > 0 {
                assert_eq!(
                    r.spec.priority,
                    Priority::Low,
                    "a {:?}-priority job was evicted on a no-spot cluster",
                    r.spec.priority
                );
                preempted_total += r.preemptions;
            }
        }
        assert_eq!(acai.engine.scheduler.counters().evictions, preempted_total);
    });
}

#[test]
fn prop_job_timelines_are_complete_and_phases_account_for_runtime() {
    use acai::cluster::{ClusterConfig, NodeSpec};
    use acai::engine::Priority;
    use acai::obs::job_phases;
    // Every terminal job must own exactly one gap-free span chain
    // (enqueue → ... → one terminal event), and the derived phase
    // durations must account for the billed runtime exactly.
    property("trace span-chain completeness", 12, |g| {
        let config = PlatformConfig {
            cluster: ClusterConfig::fixed(NodeSpec::new(8.0, 8192), g.usize(1..3)),
            quota_k: 8,
            ..Default::default()
        };
        let acai = Acai::boot(config).unwrap();
        let p = ProjectId(1);
        let payload = vec![7u8; g.usize(1..5000)];
        acai.datalake.storage.upload(p, &[("/d", payload.as_slice())]).unwrap();
        acai.datalake.filesets.create(p, "in", &["/d"], "u").unwrap();
        let prios = [Priority::Low, Priority::Normal, Priority::High];
        let mut ids = Vec::new();
        for i in 0..g.usize(4..16) {
            ids.push(
                acai.engine
                    .submit(JobSpec {
                        project: p,
                        user: UserId(g.usize(1..3) as u64),
                        name: format!("t{i}"),
                        command: format!("python train_mnist.py --epoch {}", g.usize(1..5)),
                        input_fileset: "in".into(),
                        output_fileset: format!("o{i}"),
                        resources: ResourceConfig::new(g.usize(1..5) as f64, 1024),
                        pool: None,
                        data_commit: None,
                        priority: *g.pick(&prios),
                        gang: g.usize(1..3) as u32,
                    })
                    .unwrap(),
            );
        }
        acai.engine.run_until_idle();
        for id in ids {
            let r = acai.engine.registry.get(id).unwrap();
            assert_eq!(r.state, JobState::Finished);
            let events = acai.obs.trace.events(&id.to_string());
            // INVARIANT: the chain opens with enqueue and closes with
            // exactly one terminal event
            assert_eq!(events.first().unwrap().name, "enqueue");
            assert_eq!(events.last().unwrap().name, "complete");
            let terminals = events
                .iter()
                .filter(|e| matches!(e.name.as_str(), "complete" | "failed" | "killed"))
                .count();
            assert_eq!(terminals, 1, "job {id} has {terminals} terminal events");
            // INVARIANT: sim timestamps never run backwards
            for w in events.windows(2) {
                assert!(w[0].at <= w[1].at, "timeline of {id} runs backwards");
            }
            // INVARIANT: gap-free chain — each placement consumes an
            // open enqueue/resume, each run attempt follows a placement
            let (mut queued, mut placed) = (false, false);
            for e in &events {
                match e.name.as_str() {
                    "enqueue" | "resume" => queued = true,
                    "placement" => {
                        assert!(queued, "{id}: placement without a queue entry");
                        queued = false;
                        placed = true;
                    }
                    "run" => {
                        assert!(placed, "{id}: run attempt without a placement");
                        placed = false;
                    }
                    _ => {}
                }
            }
            // INVARIANT: transfer + retained work + preemption rework
            // account for the billed runtime exactly
            let phases = job_phases(&events);
            let runtime = r.runtime_secs.unwrap();
            let total = phases.transfer + phases.run + phases.rework;
            assert!(
                (total - runtime).abs() < 1e-6 * runtime.max(1.0),
                "{id}: phases {phases:?} sum to {total}, billed runtime {runtime}"
            );
            assert!(phases.queue_wait >= 0.0);
            assert_eq!(
                events.iter().filter(|e| e.name == "preempt").count() as u64,
                r.preemptions
            );
        }
    });
}

#[test]
fn prop_same_seed_storms_produce_bit_identical_timelines() {
    use acai::cluster::{ClusterConfig, NodeSpec};
    use acai::engine::Priority;
    // Replaying one storm on two platforms booted from the same seed
    // must yield bit-identical trace timelines: same event names, same
    // f64 timestamp bits, same span ids.
    property("trace determinism", 8, |g| {
        let seed = g.u64(1..1_000_000);
        let prios = [Priority::Low, Priority::Normal, Priority::High];
        let storm: Vec<(u64, f64, usize, usize, u32)> = (0..g.usize(3..10))
            .map(|_| {
                (
                    g.usize(1..3) as u64,  // user
                    g.usize(1..5) as f64,  // vcpus
                    g.usize(1..5),         // epochs
                    g.usize(0..3),         // priority index
                    g.usize(1..3) as u32,  // gang
                )
            })
            .collect();
        let run = || {
            let config = PlatformConfig {
                cluster: ClusterConfig::fixed(NodeSpec::new(8.0, 8192), 1),
                quota_k: 8,
                seed,
                ..Default::default()
            };
            let acai = Acai::boot(config).unwrap();
            let p = ProjectId(1);
            acai.datalake
                .storage
                .upload(p, &[("/d", b"determinism-payload")])
                .unwrap();
            acai.datalake.filesets.create(p, "in", &["/d"], "u").unwrap();
            let mut ids = Vec::new();
            for (i, (user, vcpus, epochs, pi, gang)) in storm.iter().enumerate() {
                ids.push(
                    acai.engine
                        .submit(JobSpec {
                            project: p,
                            user: UserId(*user),
                            name: format!("d{i}"),
                            command: format!("python train_mnist.py --epoch {epochs}"),
                            input_fileset: "in".into(),
                            output_fileset: format!("o{i}"),
                            resources: ResourceConfig::new(*vcpus, 1024),
                            pool: None,
                            data_commit: None,
                            priority: prios[*pi],
                            gang: *gang,
                        })
                        .unwrap(),
                );
            }
            acai.engine.run_until_idle();
            ids.into_iter()
                .map(|id| {
                    acai.obs
                        .trace
                        .events(&id.to_string())
                        .iter()
                        .map(|e| (e.name.clone(), e.at.to_bits(), e.span))
                        .collect::<Vec<_>>()
                })
                .collect::<Vec<_>>()
        };
        assert_eq!(run(), run(), "same-seed storms diverged");
    });
}

#[test]
fn prop_bytes_windows_behave_like_slices() {
    use acai::storage::Bytes;
    property("bytes windows", 100, |g| {
        let n = g.usize(0..4096);
        let raw: Vec<u8> = (0..n).map(|_| g.usize(0..256) as u8).collect();
        let bytes = Bytes::from(raw.clone());
        // INVARIANT: a window equals the same slice of the original
        let a = g.usize(0..n + 1);
        let b = g.usize(a..n + 1);
        let outer = bytes.slice(a..b);
        assert_eq!(outer, &raw[a..b]);
        // INVARIANT: slicing a slice composes (window-of-window is the
        // window of the composed range)
        let c = g.usize(0..outer.len() + 1);
        let d = g.usize(c..outer.len() + 1);
        assert_eq!(outer.slice(c..d), &raw[a + c..a + d]);
        // INVARIANT: a contiguous partition concats back to the
        // original (the zero-copy assertion for this path lives in the
        // crate's unit tests, where the cfg(test) copy counter exists)
        let mid = g.usize(0..n + 1);
        let parts = [bytes.slice(0..mid), bytes.slice(mid..n)];
        assert_eq!(Bytes::concat(&parts), raw);
    });
}

#[test]
fn prop_lane_hash_matches_scalar_oracle() {
    use acai::datalake::cas::{hash64, hash64_v1, DEFAULT_CHUNK_SIZE};
    // Independent scalar re-derivation of the v2 lane hash: same
    // FNV-style constants, lanes assembled by hand with shifts instead
    // of `from_le_bytes`, same splitmix64 finisher.
    fn oracle(bytes: &[u8]) -> u64 {
        const OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
        const PRIME: u64 = 0x0000_0100_0000_01b3;
        let mut h = OFFSET;
        let mut i = 0;
        while i + 8 <= bytes.len() {
            let mut lane = 0u64;
            for (j, &b) in bytes[i..i + 8].iter().enumerate() {
                lane |= (b as u64) << (8 * j);
            }
            h = (h ^ lane).wrapping_mul(PRIME);
            i += 8;
        }
        for &b in &bytes[i..] {
            h = (h ^ b as u64).wrapping_mul(PRIME);
        }
        // splitmix64 avalanche
        h ^= h >> 30;
        h = h.wrapping_mul(0xbf58_476d_1ce4_e5b9);
        h ^= h >> 27;
        h = h.wrapping_mul(0x94d0_49bb_1331_11eb);
        h ^ (h >> 31)
    }
    property("lane hash oracle", 60, |g| {
        // lengths span empty .. 3 chunks, crossing every lane-tail case
        let n = g.usize(0..3 * DEFAULT_CHUNK_SIZE);
        let bytes: Vec<u8> = (0..n).map(|_| g.usize(0..256) as u8).collect();
        assert_eq!(hash64(&bytes), oracle(&bytes), "lane hash diverged at len {n}");
        if n >= 9 {
            // v2 is a genuine version bump, not v1 in disguise
            assert_ne!(hash64(&bytes), hash64_v1(&bytes));
        }
    });
}

#[test]
fn prop_journal_group_commit_loses_at_most_batch_minus_one() {
    use acai::kvstore::KvStore;
    use acai::storage::DEFAULT_SHARDS;
    property("journal group commit", 20, |g| {
        let batch = g.usize(1..8);
        let puts = g.usize(0..30);
        let path = std::env::temp_dir().join(format!(
            "acai-gcj-{}-{batch}-{puts}.log",
            std::process::id()
        ));
        let _ = std::fs::remove_file(&path);
        let store = KvStore::open_with(&path, DEFAULT_SHARDS, batch).unwrap();
        for i in 0..puts {
            store.put("t", &format!("k{i:03}"), Json::from(i as u64)).unwrap();
        }
        // crash: reopen the journal WITHOUT flushing the first store
        let after = KvStore::open_with(&path, DEFAULT_SHARDS, 1).unwrap();
        // INVARIANT: a full prefix survives — exactly the flushed
        // batches, so at most batch-1 trailing records are lost...
        let survived = puts - puts % batch;
        for i in 0..survived {
            assert_eq!(
                after.get("t", &format!("k{i:03}")),
                Some(Json::from(i as u64)),
                "record {i} lost from a flushed batch (batch={batch})"
            );
        }
        // ...and nothing past the last flush leaks to disk
        for i in survived..puts {
            assert_eq!(after.get("t", &format!("k{i:03}")), None);
        }
        drop(store);
        let _ = std::fs::remove_file(&path);
    });
}
